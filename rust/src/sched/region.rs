//! Series decomposition of a graph into independently schedulable regions.
//!
//! The planner's hot path re-evaluates the exact scheduler DP for every
//! split candidate. Most rewrites only perturb a small stretch of the
//! graph, so we cut the op sequence at *series points* — boundaries that
//! exactly one tensor crosses — and evaluate each region's DP in
//! isolation. The global optimal peak is then the max over regions, and
//! unchanged regions are served from a structural memo cache instead of
//! being re-solved.
//!
//! Soundness of the cut: let boundary `p` sit after op `p` (ops are
//! id-topological, a precondition checked by [`decompose`]). If the only
//! tensor crossing `p` is op `p`'s output `out_p`, then in *any* valid
//! schedule every op `≤ p` runs before every op `> p`:
//!
//! - every op `> p` is a transitive consumer of `out_p` (its activation
//!   inputs are either `out_p` itself or outputs of ops in `(p, ·)` —
//!   anything produced at `≤ p` and consumed later would be a second
//!   crosser), so it runs after op `p`;
//! - every op `< p` is a transitive ancestor of op `p` (its output is not
//!   a graph output and all its consumers are `≤ p`, again because a
//!   later consumer would make it a second crosser; walking consumers
//!   reaches op `p`), so it runs before op `p`.
//!
//! Regions therefore cannot interleave, the live set at the boundary is
//! exactly `{out_p}`, and `optimal(g).peak == max_k region_peak(k)`
//! *exactly* — not a bound. Graphs violating the preconditions (non
//! id-topological, dead tensors, zero-input ops) degrade to a single
//! whole-graph region, which is just the ordinary DP.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use super::optimal::DEFAULT_STATE_LIMIT;
use super::{accumulators, Opts, OptimalError};
use crate::graph::{Graph, OpId, TensorId};
use crate::util::bitset::BitSet;

/// A maximal run of consecutive ops `[lo, hi]` whose schedule is
/// independent of the rest of the graph, plus the tensors that must be
/// held at its end (`out_hi`, or the graph outputs for the last region).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Region {
    pub lo: OpId,
    pub hi: OpId,
    pub hold: Vec<TensorId>,
}

/// Cut the graph at series points. Always returns at least one region
/// covering every op; returns a single whole-graph region when the
/// decomposition preconditions do not hold.
pub fn decompose(g: &Graph) -> Vec<Region> {
    let n = g.ops.len();
    if n == 0 {
        return Vec::new();
    }
    let whole = || {
        vec![Region { lo: 0, hi: n - 1, hold: g.outputs.clone() }]
    };

    // Activation-consumer steps, computed from op inputs (the tensor
    // `consumers` field may also record weight uses).
    let nt = g.tensors.len();
    let mut last_use = vec![None::<usize>; nt];
    let mut used = vec![false; nt];
    for op in &g.ops {
        for &t in &op.inputs {
            used[t] = true;
            last_use[t] = Some(last_use[t].map_or(op.id, |l: usize| l.max(op.id)));
            // Precondition: op ids are topological.
            if let Some(p) = g.tensors[t].producer {
                if p >= op.id {
                    return whole();
                }
            }
        }
    }

    // Boundaries before an op with no activation inputs are invalid: such
    // an op is not a descendant of any cut tensor and could legally run
    // anywhere, so regions before it could interleave with it.
    let mut min_boundary = 0usize;
    for op in &g.ops {
        if op.inputs.is_empty() {
            min_boundary = min_boundary.max(op.id);
        }
    }

    // Crossing count per boundary p (p separates op p from op p+1).
    // Tensor t crosses p iff produced at ≤ p and still needed after p.
    let mut diff = vec![0isize; n + 1];
    for t in &g.tensors {
        if t.is_weight {
            continue;
        }
        let participates = t.producer.is_some() || g.inputs.contains(&t.id);
        if !participates {
            continue;
        }
        let is_output = g.outputs.contains(&t.id);
        if !used[t.id] && !is_output {
            // Dead tensor: the DP never schedules its producer (and a
            // consumerless graph input never enters any DP state), so the
            // region accounting would diverge from `optimal`. Bail out.
            return whole();
        }
        // Crosses boundaries [produced, last-1]: alive at boundary p iff
        // produced at ≤ p (inputs count as produced before op 0) and
        // still needed by an op > p (outputs are needed past every op).
        let produced = t.producer.unwrap_or(0);
        let last = if is_output { n } else { last_use[t.id].unwrap_or(0) };
        if last == 0 {
            continue;
        }
        let hi = (last - 1).min(n.saturating_sub(2));
        if produced <= hi {
            diff[produced] += 1;
            diff[hi + 1] -= 1;
        }
    }

    let mut cuts = Vec::new();
    let mut running = 0isize;
    for p in 0..n.saturating_sub(1) {
        running += diff[p];
        if running == 1 && p >= min_boundary {
            cuts.push(p);
        }
    }

    let mut regions = Vec::with_capacity(cuts.len() + 1);
    let mut lo = 0usize;
    for &p in &cuts {
        regions.push(Region { lo, hi: p, hold: vec![g.ops[p].output] });
        lo = p + 1;
    }
    regions.push(Region { lo, hi: n - 1, hold: g.outputs.clone() });
    regions
}

/// A region re-expressed over dense local tensor ids, together with its
/// canonical structural key. Two regions with equal keys have identical
/// DP subproblems (tensor sizes, producer structure, in-place flags and
/// end state all match), independent of op/tensor numbering and names —
/// which is what lets the memo survive the id renumbering a split
/// rewrite applies to everything downstream of the rewritten segment.
struct LocalRegion {
    key: Vec<u64>,
    bytes: Vec<usize>,
    ops: Vec<LocalOp>,
    hold: Vec<usize>,
}

struct LocalOp {
    inputs: Vec<usize>,
    output: usize,
    inplace: bool,
}

fn build_local(g: &Graph, r: &Region, acc: &[Option<TensorId>]) -> LocalRegion {
    let mut ids: HashMap<TensorId, usize> = HashMap::new();
    let mut bytes = Vec::new();
    let mut local = |t: TensorId, bytes: &mut Vec<usize>, ids: &mut HashMap<TensorId, usize>| {
        *ids.entry(t).or_insert_with(|| {
            bytes.push(g.tensors[t].bytes());
            bytes.len() - 1
        })
    };
    let mut ops = Vec::with_capacity(r.hi - r.lo + 1);
    for op in &g.ops[r.lo..=r.hi] {
        let inputs = op.inputs.iter().map(|&t| local(t, &mut bytes, &mut ids)).collect();
        let output = local(op.output, &mut bytes, &mut ids);
        ops.push(LocalOp { inputs, output, inplace: acc[op.id].is_some() });
    }
    let hold: Vec<usize> = r.hold.iter().map(|&t| local(t, &mut bytes, &mut ids)).collect();

    let mut key = Vec::with_capacity(2 * bytes.len() + 4 * ops.len() + hold.len() + 3);
    key.push(bytes.len() as u64);
    key.extend(bytes.iter().map(|&b| b as u64));
    key.push(ops.len() as u64);
    for op in &ops {
        key.push(op.inputs.len() as u64);
        key.extend(op.inputs.iter().map(|&i| i as u64));
        key.push(op.output as u64);
        key.push(op.inplace as u64);
    }
    key.push(hold.len() as u64);
    key.extend(hold.iter().map(|&i| i as u64));

    LocalRegion { key, bytes, ops, hold }
}

/// Peak-only Algorithm-1 DP over a region's local ids — the same
/// recurrence as [`super::optimal`], minus order reconstruction.
fn local_peak(r: &LocalRegion, limit: usize) -> Result<usize, OptimalError> {
    let n = r.bytes.len();
    let mut has_producer = vec![false; n];
    let mut producer_inputs: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut inplace = vec![false; n];
    let mut ancestors: Vec<BitSet> = (0..n).map(|_| BitSet::new(n)).collect();
    for op in &r.ops {
        has_producer[op.output] = true;
        producer_inputs[op.output] = op.inputs.clone();
        inplace[op.output] = op.inplace;
        let mut a = BitSet::new(n);
        for &i in &op.inputs {
            a.insert(i);
            a.union_with(&ancestors[i]);
        }
        ancestors[op.output] = a;
    }

    struct Rec<'a> {
        bytes: &'a [usize],
        has_producer: Vec<bool>,
        producer_inputs: Vec<Vec<usize>>,
        inplace: Vec<bool>,
        ancestors: Vec<BitSet>,
        memo: HashMap<BitSet, usize>,
        limit: usize,
    }
    impl Rec<'_> {
        fn sum_bytes(&self, x: &BitSet) -> usize {
            x.iter().map(|t| self.bytes[t]).sum()
        }
        fn mem(&mut self, x: &BitSet) -> Result<usize, OptimalError> {
            if let Some(&v) = self.memo.get(x) {
                return Ok(v);
            }
            if self.memo.len() >= self.limit {
                return Err(OptimalError::StateLimitExceeded {
                    states: self.memo.len(),
                    limit: self.limit,
                });
            }
            if !x.iter().any(|t| self.has_producer[t]) {
                let v = self.sum_bytes(x);
                self.memo.insert(x.clone(), v);
                return Ok(v);
            }
            let mut best = usize::MAX;
            let candidates: Vec<usize> = x.iter().filter(|&t| self.has_producer[t]).collect();
            for xt in candidates {
                if x.iter().any(|r| r != xt && self.ancestors[r].contains(xt)) {
                    continue;
                }
                let mut next = x.without(xt);
                for &i in &self.producer_inputs[xt] {
                    next.insert(i);
                }
                let x_bytes = if self.inplace[xt] { 0 } else { self.bytes[xt] };
                let step = self.sum_bytes(&next) + x_bytes
                    - next.contains(xt).then_some(x_bytes).unwrap_or(0);
                let rec = self.mem(&next)?;
                best = best.min(rec.max(step));
            }
            if best == usize::MAX {
                return Err(OptimalError::InvalidGraph(format!(
                    "region DP: no valid un-application for state {x:?}"
                )));
            }
            self.memo.insert(x.clone(), best);
            Ok(best)
        }
    }

    let mut rec = Rec {
        bytes: &r.bytes,
        has_producer,
        producer_inputs,
        inplace,
        ancestors,
        memo: HashMap::new(),
        limit,
    };
    let start = BitSet::from_iter(n, r.hold.iter().copied());
    rec.mem(&start)
}

/// Cross-candidate memo of region peaks, keyed by canonical region
/// structure. Shared across planner threads; hit/miss counters feed the
/// planner telemetry. A concurrent duplicate compute is benign (both
/// threads derive the identical value).
#[derive(Debug, Default)]
pub struct RegionCache {
    map: Mutex<HashMap<Vec<u64>, usize>>,
    lookups: AtomicUsize,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl RegionCache {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn lookups(&self) -> usize {
        self.lookups.load(Ordering::Relaxed)
    }
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Exact optimal peak via series decomposition and the region memo.
/// Equals `optimal(g)?.0.peak_bytes` whenever both succeed; errors
/// (state-limit blowups) propagate so callers can fall back to the full
/// scheduler.
pub fn fast_optimal_peak(g: &Graph, cache: &RegionCache) -> Result<usize, OptimalError> {
    fast_optimal_peak_opts(g, Opts::default(), cache)
}

/// [`fast_optimal_peak`] under explicit accumulator options.
pub fn fast_optimal_peak_opts(
    g: &Graph,
    opts: Opts,
    cache: &RegionCache,
) -> Result<usize, OptimalError> {
    if g.ops.is_empty() {
        return Ok(g.outputs.iter().map(|&t| g.tensors[t].bytes()).sum());
    }
    let acc = accumulators(g, opts);
    let mut peak = 0usize;
    for r in decompose(g) {
        let local = build_local(g, &r, &acc);
        cache.lookups.fetch_add(1, Ordering::Relaxed);
        let cached = cache.map.lock().unwrap().get(&local.key).copied();
        let v = match cached {
            Some(v) => {
                cache.hits.fetch_add(1, Ordering::Relaxed);
                v
            }
            None => {
                cache.misses.fetch_add(1, Ordering::Relaxed);
                let v = local_peak(&local, DEFAULT_STATE_LIMIT)?;
                cache.map.lock().unwrap().insert(local.key, v);
                v
            }
        };
        peak = peak.max(v);
    }
    Ok(peak)
}

/// Admissible lower bound on the optimal peak, from working sets alone:
/// every op must hold its distinct activation inputs plus its output
/// (zero when the output shares an accumulator buffer per
/// [`super::elided_accumulators`]); all consumed graph inputs coexist
/// before the first op; all graph outputs coexist after the last. Never
/// exceeds `optimal(g)?.0.peak_bytes`, so pruning a candidate whose
/// bound already meets the incumbent peak is lossless.
pub fn peak_lower_bound(g: &Graph) -> usize {
    let acc = accumulators(g, Opts::default());
    let mut lb = 0usize;
    for op in &g.ops {
        let mut ins: Vec<TensorId> = op.inputs.clone();
        ins.sort_unstable();
        ins.dedup();
        let mut step: usize = ins.iter().map(|&t| g.tensors[t].bytes()).sum();
        if acc[op.id].is_none() {
            step += g.tensors[op.output].bytes();
        }
        lb = lb.max(step);
    }
    let mut used = vec![false; g.tensors.len()];
    for op in &g.ops {
        for &t in &op.inputs {
            used[t] = true;
        }
    }
    let inputs: usize =
        g.inputs.iter().filter(|&&t| used[t]).map(|&t| g.tensors[t].bytes()).sum();
    let outputs: usize = g.outputs.iter().map(|&t| g.tensors[t].bytes()).sum();
    lb.max(inputs).max(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DType;
    use crate::models;
    use crate::sched;
    use crate::util::rng::Rng;

    fn assert_fast_matches_optimal(g: &Graph) {
        let cache = RegionCache::new();
        let fast = fast_optimal_peak(g, &cache).expect("fast peak");
        let (s, _) = sched::optimal(g).expect("optimal");
        assert_eq!(fast, s.peak_bytes, "fast != optimal on {}", g.name);
        // Second evaluation of the same graph is served fully from cache.
        let before = cache.misses();
        let again = fast_optimal_peak(g, &cache).expect("fast peak (cached)");
        assert_eq!(again, fast);
        assert_eq!(cache.misses(), before, "unexpected recompute on {}", g.name);
        assert_eq!(cache.lookups(), cache.hits() + cache.misses());
    }

    #[test]
    fn figure1_decomposes_at_the_first_conv() {
        let g = sched::tests::figure1_graph();
        let regions = decompose(&g);
        assert_eq!(regions.len(), 2, "{regions:?}");
        assert_eq!((regions[0].lo, regions[0].hi), (0, 0));
        assert_eq!((regions[1].lo, regions[1].hi), (1, 6));
        assert_fast_matches_optimal(&g);
        let cache = RegionCache::new();
        assert_eq!(fast_optimal_peak(&g, &cache).unwrap(), 4960);
    }

    #[test]
    fn fast_peak_matches_optimal_on_the_zoo() {
        for g in [
            models::figure1(),
            models::mobilenet_v1_025(DType::I8),
            models::swiftnet_cell(DType::I8),
            models::resnet_micro(DType::I8),
            models::audionet(DType::I8),
            models::streamnet(DType::I8),
            models::tiny_cnn(DType::I8),
        ] {
            assert_fast_matches_optimal(&g);
        }
    }

    #[test]
    fn fast_peak_matches_optimal_on_random_graphs() {
        let mut rng = Rng::new(41);
        for i in 0..40 {
            let g = models::synth::random_dag(&mut rng, 4 + i % 9);
            assert_fast_matches_optimal(&g);
        }
        let mut rng = Rng::new(42);
        for _ in 0..10 {
            let g = models::synth::series_parallel(&mut rng, 3, 2);
            assert_fast_matches_optimal(&g);
        }
    }

    #[test]
    fn lower_bound_is_admissible() {
        let mut rng = Rng::new(43);
        let mut graphs = vec![
            models::figure1(),
            models::mobilenet_v1_025(DType::I8),
            models::audionet(DType::I8),
            models::streamnet(DType::I8),
        ];
        for i in 0..30 {
            graphs.push(models::synth::random_dag(&mut rng, 4 + i % 9));
        }
        for g in graphs {
            let (s, _) = sched::optimal(&g).expect("optimal");
            let lb = peak_lower_bound(&g);
            assert!(lb <= s.peak_bytes, "lb {} > optimal {} on {}", lb, s.peak_bytes, g.name);
            assert!(lb > 0);
        }
    }

    #[test]
    fn chain_graphs_cut_at_every_boundary() {
        let g = models::mobilenet_v1_025(DType::I8);
        let regions = decompose(&g);
        // One long chain: every boundary is a series point.
        assert_eq!(regions.len(), g.ops.len());
    }

    #[test]
    fn residual_blocks_stay_in_one_region() {
        let g = models::resnet_micro(DType::I8);
        let regions = decompose(&g);
        assert!(regions.len() > 1, "{regions:?}");
        for r in &regions {
            assert!(r.lo <= r.hi);
        }
        // Regions tile the op range exactly.
        assert_eq!(regions[0].lo, 0);
        assert_eq!(regions.last().unwrap().hi, g.ops.len() - 1);
        for w in regions.windows(2) {
            assert_eq!(w[0].hi + 1, w[1].lo);
        }
    }
}

//! Algorithm 1 — memory-optimal operator ordering.
//!
//! [`optimal`] is the paper's memoized dynamic program over *sets of
//! tensors*: `MEM(X)` is the minimal peak memory needed to produce (and hold
//! simultaneously) the tensors in `X`. It enumerates execution schedules
//! backwards, "un-applying" the producer of one tensor of `X` at a time;
//! a producer may be un-applied only if its output is not an ancestor of any
//! other tensor in `X` (otherwise it would have to execute twice). Worst
//! case O(|V|·2^|V|), but the memoized state space for CNN-like graphs is
//! tiny because only downward-closed frontiers are reachable.
//!
//! One faithful generalization: the paper filters producer-less tensors
//! ("constants" — for us, graph inputs) out of the recursion and adds their
//! sizes back additively (line 18). That is exact when each graph input has
//! a single consumer (true for all models in the paper) but double-counts
//! inputs consumed by several operators; we instead keep producer-less
//! tensors inside the state, which is exact in both cases and identical on
//! the paper's graphs.
//!
//! [`optimal_bnb`] reaches the same optimum by forward branch-and-bound
//! (greedy incumbent, running-peak pruning, dominance memo on the
//! executed-op set). It is benchmarked against the DP in the
//! `scheduler_scaling` ablation.

use std::collections::HashMap;

use super::{greedy_min_increase, peak_of, peak_of_opts, Opts, Schedule};
use crate::graph::{Graph, TensorId};
use crate::util::bitset::BitSet;

/// Why the optimal scheduler gave up.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OptimalError {
    /// The memo table exceeded the state budget (graph too entangled).
    StateLimitExceeded { states: usize, limit: usize },
    /// The graph failed validation.
    InvalidGraph(String),
}

impl std::fmt::Display for OptimalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OptimalError::StateLimitExceeded { states, limit } => {
                write!(f, "optimal scheduler exceeded state limit ({states} > {limit})")
            }
            OptimalError::InvalidGraph(m) => write!(f, "invalid graph: {m}"),
        }
    }
}

impl std::error::Error for OptimalError {}

/// Search statistics (reported by the CLI and the scaling ablation).
#[derive(Debug, Clone, Default)]
pub struct OptimalStats {
    /// Distinct memoized states.
    pub states: usize,
    /// Memo hits.
    pub hits: usize,
    /// Recursive expansions.
    pub expansions: usize,
}

struct Dp<'g> {
    g: &'g Graph,
    bytes: Vec<usize>,
    /// `inplace[t]`: the producer of tensor `t` may accumulate in place
    /// (Opts::inplace_add), so `t` adds no bytes at its own step.
    inplace: Vec<bool>,
    /// Activation inputs of each tensor's producer (empty for inputs).
    producer_inputs: Vec<Vec<TensorId>>,
    has_producer: Vec<bool>,
    ancestors: Vec<BitSet>,
    /// state → (minimal peak, chosen tensor to un-apply last).
    memo: HashMap<BitSet, (usize, Option<TensorId>)>,
    stats: OptimalStats,
    limit: usize,
}

impl<'g> Dp<'g> {
    fn new(g: &'g Graph, limit: usize, opts: Opts) -> Self {
        let n = g.tensors.len();
        let mut producer_inputs = vec![Vec::new(); n];
        let mut has_producer = vec![false; n];
        for op in &g.ops {
            has_producer[op.output] = true;
            producer_inputs[op.output] = op.inputs.clone();
        }
        // Structural (join-elision) accumulators always share their
        // buffer; `Add` accumulation joins them under `opts.inplace_add`.
        let mut inplace = vec![false; n];
        for (op, acc) in g.ops.iter().zip(super::accumulators(g, opts)) {
            if acc.is_some() {
                inplace[op.output] = true;
            }
        }
        Dp {
            g,
            bytes: g.tensors.iter().map(|t| t.bytes()).collect(),
            inplace,
            producer_inputs,
            has_producer,
            ancestors: g.tensor_ancestors(),
            memo: HashMap::new(),
            stats: OptimalStats::default(),
            limit,
        }
    }

    fn sum_bytes(&self, x: &BitSet) -> usize {
        x.iter().map(|t| self.bytes[t]).sum()
    }

    /// `MEM(X)`: minimal peak memory to produce and simultaneously hold the
    /// tensors of `X`.
    fn mem(&mut self, x: &BitSet) -> Result<usize, OptimalError> {
        if let Some(&(v, _)) = self.memo.get(x) {
            self.stats.hits += 1;
            return Ok(v);
        }
        if self.memo.len() >= self.limit {
            return Err(OptimalError::StateLimitExceeded {
                states: self.memo.len(),
                limit: self.limit,
            });
        }
        self.stats.expansions += 1;

        // Base case: nothing left to un-apply.
        if !x.iter().any(|t| self.has_producer[t]) {
            let v = self.sum_bytes(x);
            self.memo.insert(x.clone(), (v, None));
            self.stats.states = self.memo.len();
            return Ok(v);
        }

        let mut best = usize::MAX;
        let mut best_choice = None;
        let candidates: Vec<TensorId> = x.iter().filter(|&t| self.has_producer[t]).collect();
        for xt in candidates {
            // Un-applying producer(xt) is invalid if xt is an ancestor of
            // any other tensor that must remain produced — its producer
            // would have to run again later (Algorithm 1, line 11).
            let invalid = x.iter().any(|r| r != xt && self.ancestors[r].contains(xt));
            if invalid {
                continue;
            }
            // Next state: (X \ {xt}) ∪ inputs(producer(xt)).
            let mut next = x.without(xt);
            for &i in &self.producer_inputs[xt] {
                next.insert(i);
            }
            // Working set during producer(xt): X ∪ inputs = next ∪ {xt}.
            // Under in-place accumulation xt shares its accumulator's
            // buffer and adds no bytes of its own.
            let x_bytes = if self.inplace[xt] { 0 } else { self.bytes[xt] };
            let step = self.sum_bytes(&next)
                + x_bytes
                - next.contains(xt).then_some(x_bytes).unwrap_or(0);
            let rec = self.mem(&next)?;
            let m = rec.max(step);
            if m < best {
                best = m;
                best_choice = Some(xt);
            }
        }
        debug_assert!(best_choice.is_some(), "no valid un-application for state {x:?}");
        self.memo.insert(x.clone(), (best, best_choice));
        self.stats.states = self.memo.len();
        Ok(best)
    }

    /// Walk the memoized choices from the output state down to the inputs,
    /// emitting producers in reverse execution order.
    fn reconstruct(&self, start: &BitSet) -> Vec<usize> {
        let mut order_rev = Vec::with_capacity(self.g.ops.len());
        let mut state = start.clone();
        loop {
            let (_, choice) = self.memo[&state];
            match choice {
                None => break,
                Some(xt) => {
                    order_rev.push(self.g.tensors[xt].producer.expect("choice has producer"));
                    let mut next = state.without(xt);
                    for &i in &self.producer_inputs[xt] {
                        next.insert(i);
                    }
                    state = next;
                }
            }
        }
        order_rev.reverse();
        order_rev
    }
}

/// Default memo-state budget. CNN-style graphs stay in the hundreds of
/// states; pathological dense DAGs can blow up exponentially, so we cap.
pub const DEFAULT_STATE_LIMIT: usize = 4_000_000;

/// Find a peak-memory-optimal execution order (Algorithm 1).
pub fn optimal(g: &Graph) -> Result<(Schedule, OptimalStats), OptimalError> {
    optimal_with_limit(g, DEFAULT_STATE_LIMIT)
}

/// [`optimal`] with scheduling options (in-place accumulation, §6).
pub fn optimal_opts(g: &Graph, opts: Opts) -> Result<(Schedule, OptimalStats), OptimalError> {
    optimal_full(g, DEFAULT_STATE_LIMIT, opts)
}

/// [`optimal`] with an explicit memo-state budget.
pub fn optimal_with_limit(
    g: &Graph,
    limit: usize,
) -> Result<(Schedule, OptimalStats), OptimalError> {
    optimal_full(g, limit, Opts::default())
}

fn optimal_full(
    g: &Graph,
    limit: usize,
    opts: Opts,
) -> Result<(Schedule, OptimalStats), OptimalError> {
    g.validate().map_err(|e| OptimalError::InvalidGraph(e.to_string()))?;
    let n = g.tensors.len();
    let mut dp = Dp::new(g, limit, opts);
    let start = BitSet::from_iter(n, g.outputs.iter().copied());
    let peak = dp.mem(&start)?;
    let order = dp.reconstruct(&start);
    debug_assert_eq!(order.len(), g.ops.len(), "reconstruction incomplete");
    g.check_order(&order)
        .map_err(|e| OptimalError::InvalidGraph(format!("reconstructed order invalid: {e}")))?;
    debug_assert_eq!(
        peak_of_opts(g, &order, opts),
        peak,
        "DP value vs simulated peak mismatch"
    );
    Ok((Schedule { order, peak_bytes: peak }, dp.stats))
}

/// Forward branch-and-bound search for the same optimum.
///
/// Starts from the greedy min-increase incumbent, explores ready-op choices
/// depth-first, prunes when the running peak already matches/exceeds the
/// incumbent, and keeps a dominance memo `executed-op set → best running
/// peak seen` (reaching the same executed set with a worse running peak can
/// never help). Exact, often faster than the DP on wide graphs; ablated in
/// `scheduler_scaling`.
pub fn optimal_bnb(g: &Graph) -> Result<(Schedule, OptimalStats), OptimalError> {
    g.validate().map_err(|e| OptimalError::InvalidGraph(e.to_string()))?;
    let n_ops = g.ops.len();
    let n_t = g.tensors.len();

    let incumbent = greedy_min_increase(g);
    let mut best_peak = incumbent.peak_bytes;
    let mut best_order = incumbent.order;

    // Per-tensor remaining-consumer counts and output flags.
    let mut remaining_init = vec![0u32; n_t];
    for op in &g.ops {
        for &t in &op.inputs {
            remaining_init[t] += 1;
        }
    }
    let mut is_output = vec![false; n_t];
    for &t in &g.outputs {
        is_output[t] = true;
    }
    // Ready = ops whose activation inputs are all produced.
    let mut waiting = vec![0usize; n_ops];
    for op in &g.ops {
        waiting[op.id] =
            op.inputs.iter().filter(|&&t| g.tensors[t].producer.is_some()).count();
    }

    struct Search<'g> {
        g: &'g Graph,
        bytes: Vec<usize>,
        /// Per-op step-peak discount: a join-elided slice's output shares
        /// its accumulator's buffer, so its bytes don't count at its own
        /// step (live tracking still carries the full size; the
        /// accumulator's death at the same step rebalances it).
        discount: Vec<usize>,
        is_output: Vec<bool>,
        dominance: HashMap<BitSet, usize>,
        stats: OptimalStats,
    }

    #[allow(clippy::too_many_arguments)]
    fn dfs(
        s: &mut Search,
        executed: &mut BitSet,
        order: &mut Vec<usize>,
        waiting: &mut Vec<usize>,
        remaining: &mut Vec<u32>,
        live_bytes: usize,
        run_peak: usize,
        best_peak: &mut usize,
        best_order: &mut Vec<usize>,
    ) {
        if run_peak >= *best_peak {
            return; // cannot strictly improve
        }
        if order.len() == s.g.ops.len() {
            *best_peak = run_peak;
            *best_order = order.clone();
            return;
        }
        match s.dominance.get(executed) {
            Some(&seen) if seen <= run_peak => return,
            _ => {
                s.dominance.insert(executed.clone(), run_peak);
                s.stats.states = s.dominance.len();
            }
        }
        s.stats.expansions += 1;

        let ready: Vec<usize> =
            (0..s.g.ops.len()).filter(|&o| !executed.contains(o) && waiting[o] == 0).collect();
        // Order choices by resulting live size (cheapest first) — finds
        // good schedules early, tightening the bound.
        let mut choices: Vec<(usize, usize)> = ready
            .iter()
            .map(|&o| {
                let out = s.g.ops[o].output;
                let mut delta = s.bytes[out] as isize;
                for &t in &s.g.ops[o].inputs {
                    if remaining[t] == 1 && !s.is_output[t] {
                        delta -= s.bytes[t] as isize;
                    }
                }
                ((live_bytes as isize + delta.max(0)) as usize, o)
            })
            .collect();
        choices.sort_unstable();

        for (_, o) in choices {
            let op = &s.g.ops[o];
            let out = op.output;
            // Apply.
            let step_live = live_bytes + s.bytes[out];
            let new_peak = run_peak.max(step_live - s.discount[o]);
            if new_peak >= *best_peak {
                continue;
            }
            let mut after = step_live;
            for &t in &op.inputs {
                remaining[t] -= 1;
                if remaining[t] == 0 && !s.is_output[t] {
                    after -= s.bytes[t];
                }
            }
            if remaining[out] == 0 && !s.is_output[out] {
                after -= s.bytes[out];
            }
            executed.insert(o);
            order.push(o);
            for &c in &s.g.tensors[out].consumers {
                if s.g.ops[c].inputs.contains(&out) {
                    waiting[c] -= 1;
                }
            }

            dfs(s, executed, order, waiting, remaining, after, new_peak, best_peak, best_order);

            // Undo.
            for &c in &s.g.tensors[out].consumers {
                if s.g.ops[c].inputs.contains(&out) {
                    waiting[c] += 1;
                }
            }
            order.pop();
            executed.remove(o);
            for &t in &op.inputs {
                remaining[t] += 1;
            }
        }
    }

    let bytes: Vec<usize> = g.tensors.iter().map(|t| t.bytes()).collect();
    let discount: Vec<usize> = g
        .ops
        .iter()
        .zip(super::elided_accumulators(g))
        .map(|(op, acc)| if acc.is_some() { bytes[op.output] } else { 0 })
        .collect();
    let mut s = Search {
        g,
        bytes,
        discount,
        is_output,
        dominance: HashMap::new(),
        stats: OptimalStats::default(),
    };
    let live0: usize = g.inputs.iter().map(|&t| g.tensors[t].bytes()).sum();
    let mut executed = BitSet::new(n_ops);
    let mut order = Vec::with_capacity(n_ops);
    let mut remaining = remaining_init;
    // Allow matching the incumbent exactly: bound is strict, so bump by 1 to
    // admit equal-peak proofs (we already hold the incumbent order).
    best_peak += 1;
    dfs(
        &mut s,
        &mut executed,
        &mut order,
        &mut waiting,
        &mut remaining,
        live0,
        live0,
        &mut best_peak,
        &mut best_order,
    );
    let peak = peak_of(g, &best_order);
    Ok((Schedule { order: best_order, peak_bytes: peak }, s.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, GraphBuilder};
    use crate::sched::tests::figure1_graph;
    use crate::sched::{bruteforce, simulate};
    use crate::util::prop;

    #[test]
    fn figure1_optimal_peak_is_4960() {
        let g = figure1_graph();
        let (sched, stats) = optimal(&g).unwrap();
        assert_eq!(sched.peak_bytes, 4960);
        assert!(stats.states > 0);
        // The specific optimal order in the paper is 1,4,6,2,3,5,7; ours
        // must be *an* optimal order (there may be ties).
        let trace = simulate(&g, &sched.order);
        assert_eq!(trace.peak_bytes, 4960);
    }

    #[test]
    fn figure1_bnb_matches_dp() {
        let g = figure1_graph();
        let (dp, _) = optimal(&g).unwrap();
        let (bnb, _) = optimal_bnb(&g).unwrap();
        assert_eq!(dp.peak_bytes, bnb.peak_bytes);
    }

    #[test]
    fn linear_chain_has_single_order() {
        let mut b = GraphBuilder::new("chain");
        let mut t = b.input("x", &[100], DType::U8);
        for i in 0..6 {
            t = b.synthetic(&format!("op{i}"), &[t], 100 + i * 10, 0);
        }
        b.output(t);
        let g = b.finish().unwrap();
        let (sched, _) = optimal(&g).unwrap();
        assert_eq!(sched.order, g.default_order());
        // peak = max adjacent pair: here last two (140,150) + ... chain:
        // each step holds input+output only.
        assert_eq!(sched.peak_bytes, 140 + 150);
    }

    #[test]
    fn multi_consumer_input_not_double_counted() {
        // Graph input consumed by TWO ops — the case where the paper's
        // additive-constant shortcut would double count.
        let mut b = GraphBuilder::new("multi");
        let x = b.input("x", &[1000], DType::U8);
        let a = b.synthetic("a", &[x], 10, 0);
        let c = b.synthetic("c", &[x], 10, 0);
        let d = b.synthetic("d", &[a, c], 10, 0);
        b.output(d);
        let g = b.finish().unwrap();
        let (sched, _) = optimal(&g).unwrap();
        let bf = bruteforce(&g, usize::MAX).unwrap();
        assert_eq!(sched.peak_bytes, bf.best.peak_bytes);
        // x(1000) + a(10) + c(10) = 1020 at the second op.
        assert_eq!(sched.peak_bytes, 1020);
    }

    #[test]
    fn optimal_matches_bruteforce_on_random_dags() {
        prop::check_sized("optimal==bruteforce", 60, 3, 9, |rng, n_ops| {
            let g = crate::sched::bruteforce::tests::random_dag(rng, n_ops);
            let bf = bruteforce(&g, usize::MAX).unwrap();
            let (dp, _) = optimal(&g).unwrap();
            assert_eq!(
                dp.peak_bytes, bf.best.peak_bytes,
                "graph: {}",
                crate::graph::serde::graph_to_json(&g, None).to_string()
            );
        });
    }

    #[test]
    fn bnb_matches_bruteforce_on_random_dags() {
        prop::check_sized("bnb==bruteforce", 60, 3, 9, |rng, n_ops| {
            let g = crate::sched::bruteforce::tests::random_dag(rng, n_ops);
            let bf = bruteforce(&g, usize::MAX).unwrap();
            let (bnb, _) = optimal_bnb(&g).unwrap();
            assert_eq!(bnb.peak_bytes, bf.best.peak_bytes);
        });
    }

    #[test]
    fn state_limit_is_enforced() {
        let g = figure1_graph();
        match optimal_with_limit(&g, 2) {
            Err(OptimalError::StateLimitExceeded { .. }) => {}
            other => panic!("expected state-limit error, got {other:?}"),
        }
    }

    #[test]
    fn inplace_dp_matches_enumeration_on_random_dags() {
        use crate::sched::{all_orders, optimal_opts, peak_of_opts, Opts};
        prop::check_sized("inplace-dp==enum", 40, 3, 8, |rng, n| {
            let g = crate::sched::bruteforce::tests::random_dag(rng, n);
            let orders = all_orders(&g, 200_000).expect("small graph");
            let best = orders
                .iter()
                .map(|o| peak_of_opts(&g, o, Opts::INPLACE))
                .min()
                .unwrap();
            let (dp, _) = optimal_opts(&g, Opts::INPLACE).unwrap();
            assert_eq!(dp.peak_bytes, best);
        });
    }

    #[test]
    fn inplace_never_hurts() {
        use crate::sched::{optimal_opts, Opts};
        prop::check_sized("inplace<=plain", 40, 3, 9, |rng, n| {
            let g = crate::sched::bruteforce::tests::random_dag(rng, n);
            let (plain, _) = optimal(&g).unwrap();
            let (inp, _) = optimal_opts(&g, Opts::INPLACE).unwrap();
            assert!(inp.peak_bytes <= plain.peak_bytes);
        });
    }

    #[test]
    fn optimal_order_is_topological() {
        let g = figure1_graph();
        let (sched, _) = optimal(&g).unwrap();
        g.check_order(&sched.order).unwrap();
    }
}

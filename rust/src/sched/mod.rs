//! Execution scheduling and working-set analysis (§4 of the paper).
//!
//! The *working set* at an execution step is the pending operator's input
//! and output tensors plus every already-produced tensor still needed by a
//! later operator (§2.1). Weights are Flash-resident and never counted.
//! This module provides:
//!
//! - [`simulate`] — byte-exact working-set trace of a given execution order
//!   (regenerates the Appendix A tables).
//! - [`optimal`] — **Algorithm 1**: memoized dynamic programming over tensor
//!   sets; returns a peak-memory-optimal topological order.
//! - [`optimal_bnb`] — branch-and-bound forward search with a dominance
//!   memo; same optimum, different constant factors (ablation).
//! - [`bruteforce`] — exhaustive enumeration of all topological orders
//!   (Knuth–Szwarcfiter-style backtracking); ground truth for tests.
//! - [`greedy`] — cheap heuristics (min-increase, depth-first) used as
//!   incumbents and baselines.
//! - [`region`](self::decompose) — series decomposition of the graph into
//!   independently schedulable regions, a structural region-peak memo
//!   ([`RegionCache`]) and an admissible working-set lower bound
//!   ([`peak_lower_bound`]); together these are the split planner's
//!   incremental evaluation fast path.

pub(crate) mod bruteforce;
mod greedy;
mod optimal;
mod region;

pub use bruteforce::{all_orders, bruteforce, BruteForceResult};
pub use greedy::{greedy_depth_first, greedy_min_increase};
pub use optimal::{optimal, optimal_bnb, optimal_opts, OptimalError, OptimalStats};
pub use region::{
    decompose, fast_optimal_peak, fast_optimal_peak_opts, peak_lower_bound, Region, RegionCache,
};

use crate::graph::{Graph, OpId, TensorId};
use crate::trace::{Event, NullSink, TraceSink};

/// One step of a working-set trace: the operator executed and the tensors
/// resident in SRAM *during* its execution (inputs + output + held).
#[derive(Clone, Debug)]
pub struct Step {
    pub op: OpId,
    /// Tensors in SRAM during this step, ascending by id.
    pub resident: Vec<TensorId>,
    /// Total bytes of `resident`.
    pub bytes: usize,
}

/// Working-set trace of a complete execution order.
#[derive(Clone, Debug)]
pub struct MemTrace {
    pub order: Vec<OpId>,
    pub steps: Vec<Step>,
    /// Peak working-set size over all steps (the paper's "peak memory
    /// usage (excl. overheads)").
    pub peak_bytes: usize,
    /// Index into `steps` where the peak occurs (first occurrence).
    pub peak_step: usize,
}

impl MemTrace {
    /// Render the Appendix-A style table ("Operator | Tensors in RAM | Usage").
    pub fn render_table(&self, g: &Graph) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<24} {:<28} {:>10}\n",
            "Operator", "Tensors in RAM (op #)", "Usage (B)"
        ));
        for step in &self.steps {
            let op = &g.ops[step.op];
            let tensor_list: Vec<String> = step
                .resident
                .iter()
                .map(|&t| match g.tensors[t].producer {
                    Some(p) => format!("{}", p + 1),
                    None => "in".to_string(),
                })
                .collect();
            out.push_str(&format!(
                "{:<24} {{{}}}{:width$} {:>10}\n",
                format!("{} ({})", op.id + 1, op.kind.name()),
                tensor_list.join(","),
                "",
                step.bytes,
                width = 28usize.saturating_sub(tensor_list.join(",").len() + 2)
            ));
        }
        out.push_str(&format!("{:>63}  (peak)\n", self.peak_bytes));
        out
    }
}

/// Scheduling options.
///
/// `inplace_add` enables the §6 extension: "if one of the inputs to the
/// addition operator is not used elsewhere, the result can be accumulated
/// into it, eliminating the need for an output buffer". An `Add` is
/// eligible when one of its inputs has no other consumer, is not a graph
/// output, and matches the output size; at that step the output shares the
/// accumulator's buffer, so it contributes no extra bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Opts {
    pub inplace_add: bool,
}

impl Opts {
    pub const INPLACE: Opts = Opts { inplace_add: true };
}

/// Per-op in-place accumulator: `Some(tensor)` when the op may write its
/// output over that input's buffer under [`Opts::inplace_add`].
pub fn inplace_accumulators(g: &Graph) -> Vec<Option<TensorId>> {
    g.ops
        .iter()
        .map(|op| {
            if !matches!(op.kind, crate::graph::OpKind::Add) {
                return None;
            }
            let out_bytes = g.tensors[op.output].bytes();
            op.inputs.iter().copied().find(|&t| eligible_accumulator(g, t, out_bytes))
        })
        .collect()
}

fn eligible_accumulator(g: &Graph, t: TensorId, out_bytes: usize) -> bool {
    let tens = &g.tensors[t];
    let consumers = tens.consumers.iter().filter(|&&c| g.ops[c].inputs.contains(&t)).count();
    consumers == 1 && !g.outputs.contains(&t) && tens.bytes() == out_bytes
}

/// Per-op *structural* accumulator: `Some(tensor)` when the op's kind makes
/// in-place execution part of its semantics, independent of [`Opts`]. A
/// [`crate::graph::OpKind::PartialInto`] slice (streaming concat elision)
/// writes its output band through its accumulator input (`inputs[1]`), so
/// the output shares that buffer and contributes no bytes of its own at
/// its step — this is what collapses the 2×output floor at a split join.
///
/// The same safety conditions as [`inplace_accumulators`] are verified
/// (sole consumer, not a graph output, matching size). The split rewriter
/// guarantees them; if a hand-built graph violates them the accounting
/// soundly degrades to no sharing (and the interpreter materializes a
/// fresh buffer instead of reusing the handle).
pub fn elided_accumulators(g: &Graph) -> Vec<Option<TensorId>> {
    g.ops
        .iter()
        .map(|op| {
            if !matches!(op.kind, crate::graph::OpKind::PartialInto { .. }) {
                return None;
            }
            let &acc = op.inputs.get(1)?;
            eligible_accumulator(g, acc, g.tensors[op.output].bytes()).then_some(acc)
        })
        .collect()
}

/// Combined per-op accumulators under `opts`: structural join-elision
/// accumulators always apply; `Add` accumulation joins them under
/// [`Opts::inplace_add`].
pub(crate) fn accumulators(g: &Graph, opts: Opts) -> Vec<Option<TensorId>> {
    let mut acc = elided_accumulators(g);
    if opts.inplace_add {
        for (a, b) in acc.iter_mut().zip(inplace_accumulators(g)) {
            if a.is_none() {
                *a = b;
            }
        }
    }
    acc
}

impl MemTrace {
    /// ASCII bar chart of per-step memory usage (the plots the paper's tool
    /// produces, in terminal form).
    pub fn render_chart(&self, g: &Graph, width: usize) -> String {
        let mut out = String::new();
        let peak = self.peak_bytes.max(1);
        for (i, step) in self.steps.iter().enumerate() {
            let bar = (step.bytes * width).div_ceil(peak);
            let marker = if i == self.peak_step { " ◀ peak" } else { "" };
            out.push_str(&format!(
                "op {:>3} {:<18} |{:<w$}| {:>8} B{}
",
                step.op + 1,
                g.ops[step.op].name,
                "█".repeat(bar),
                step.bytes,
                marker,
                w = width
            ));
        }
        out
    }

    /// CSV dump (`step,op,op_name,bytes,resident`) for external plotting.
    pub fn to_csv(&self, g: &Graph) -> String {
        let mut out = String::from("step,op,op_name,bytes,resident\n");
        for (i, step) in self.steps.iter().enumerate() {
            let resident: Vec<String> = step.resident.iter().map(|t| t.to_string()).collect();
            out.push_str(&format!(
                "{},{},{},{},\"{}\"\n",
                i,
                step.op,
                g.ops[step.op].name,
                step.bytes,
                resident.join(" ")
            ));
        }
        out
    }
}

/// A schedule: an execution order plus its peak working-set size.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    pub order: Vec<OpId>,
    pub peak_bytes: usize,
}

/// Compute the working-set trace of `order` on `g`.
///
/// Semantics (matching the paper's Appendix A accounting):
/// - graph inputs are resident from the start until their last consumer has
///   executed;
/// - an operator's output becomes resident at its step;
/// - a tensor is freed immediately after its last consumer executes, unless
///   it is a graph output (graph outputs stay resident to the end);
/// - weights are never resident (they live in Flash).
///
/// Panics if `order` is not a valid topological order (callers validate via
/// [`Graph::check_order`]).
pub fn simulate(g: &Graph, order: &[OpId]) -> MemTrace {
    simulate_opts(g, order, Opts::default())
}

/// [`simulate`] with scheduling options (in-place accumulation).
pub fn simulate_opts(g: &Graph, order: &[OpId], opts: Opts) -> MemTrace {
    simulate_traced(g, order, opts, &mut NullSink)
}

/// [`simulate_opts`] with an observability sink: emits one
/// [`Event::TensorAlloc`] when a tensor becomes resident, one
/// [`Event::OpExec`] per executed step (live-set bytes *during* the op),
/// one [`Event::ElidedAccum`] per in-place-accumulation hit, and one
/// [`Event::TensorFree`] when a tensor is reclaimed. Tensors still
/// resident after the last op (graph outputs and held inputs) are freed
/// at `step == order.len()`, so the event stream is balanced: every
/// alloc has exactly one free. With a [`NullSink`] no event is built and
/// this is byte-for-byte the untraced simulation.
pub fn simulate_traced(
    g: &Graph,
    order: &[OpId],
    opts: Opts,
    sink: &mut dyn TraceSink,
) -> MemTrace {
    g.check_order(order).expect("simulate: invalid execution order");
    let traced = sink.enabled();
    let acc = accumulators(g, opts);
    let n = g.tensors.len();
    // Remaining consumer count per tensor (activation consumers only).
    let mut remaining = vec![0usize; n];
    for op in &g.ops {
        for &t in &op.inputs {
            remaining[t] += 1;
        }
    }
    let is_output = {
        let mut v = vec![false; n];
        for &t in &g.outputs {
            v[t] = true;
        }
        v
    };

    let mut resident = vec![false; n];
    for &t in &g.inputs {
        resident[t] = true;
        if traced {
            sink.record(Event::TensorAlloc {
                step: 0,
                tensor: t,
                name: g.tensors[t].name.clone(),
                bytes: g.tensors[t].bytes(),
                shared: false,
            });
        }
    }

    let mut steps = Vec::with_capacity(order.len());
    let mut peak = 0usize;
    let mut peak_step = 0usize;

    for (i, &opid) in order.iter().enumerate() {
        let op = &g.ops[opid];
        let elided = acc[opid].is_some();
        if traced && !resident[op.output] {
            sink.record(Event::TensorAlloc {
                step: i,
                tensor: op.output,
                name: g.tensors[op.output].name.clone(),
                bytes: g.tensors[op.output].bytes(),
                shared: elided,
            });
        }
        resident[op.output] = true;
        let live: Vec<TensorId> = (0..n).filter(|&t| resident[t]).collect();
        let mut bytes: usize = live.iter().map(|&t| g.tensors[t].bytes()).sum();
        // In-place accumulation: the output shares its accumulator's buffer.
        if let Some(a) = acc[opid] {
            let saved = g.tensors[op.output].bytes();
            bytes -= saved;
            if traced {
                sink.record(Event::ElidedAccum {
                    step: i,
                    op: opid,
                    name: op.name.clone(),
                    acc: a,
                    saved_bytes: saved,
                });
            }
        }
        if bytes > peak {
            peak = bytes;
            peak_step = i;
        }
        if traced {
            sink.record(Event::OpExec {
                step: i,
                op: opid,
                name: op.name.clone(),
                bytes,
                elided,
            });
        }
        steps.push(Step { op: opid, resident: live, bytes });
        // Reclaim inputs whose consumers are all done.
        for &t in &op.inputs {
            remaining[t] -= 1;
            if remaining[t] == 0 && !is_output[t] && resident[t] {
                resident[t] = false;
                if traced {
                    sink.record(Event::TensorFree {
                        step: i,
                        tensor: t,
                        name: g.tensors[t].name.clone(),
                        bytes: g.tensors[t].bytes(),
                    });
                }
            }
        }
        // An output with no consumers that is not a graph output would be
        // dead on arrival; reclaim it to keep accounting consistent.
        if remaining[op.output] == 0 && !is_output[op.output] && resident[op.output] {
            resident[op.output] = false;
            if traced {
                sink.record(Event::TensorFree {
                    step: i,
                    tensor: op.output,
                    name: g.tensors[op.output].name.clone(),
                    bytes: g.tensors[op.output].bytes(),
                });
            }
        }
    }

    // Balance the stream: whatever survives the schedule (graph outputs,
    // held inputs) is released past the last step.
    if traced {
        for t in 0..n {
            if resident[t] {
                sink.record(Event::TensorFree {
                    step: order.len(),
                    tensor: t,
                    name: g.tensors[t].name.clone(),
                    bytes: g.tensors[t].bytes(),
                });
            }
        }
    }

    MemTrace { order: order.to_vec(), steps, peak_bytes: peak, peak_step }
}

/// Peak working-set size of `order` without materializing the trace
/// (hot path for enumeration-based schedulers).
pub fn peak_of(g: &Graph, order: &[OpId]) -> usize {
    peak_of_opts(g, order, Opts::default())
}

/// [`peak_of`] with scheduling options.
pub fn peak_of_opts(g: &Graph, order: &[OpId], opts: Opts) -> usize {
    let acc = accumulators(g, opts);
    let n = g.tensors.len();
    let mut remaining = vec![0u32; n];
    for op in &g.ops {
        for &t in &op.inputs {
            remaining[t] += 1;
        }
    }
    let mut is_output = vec![false; n];
    for &t in &g.outputs {
        is_output[t] = true;
    }
    let mut live_bytes: usize = g.inputs.iter().map(|&t| g.tensors[t].bytes()).sum();
    let mut peak = 0usize;
    for &opid in order {
        let op = &g.ops[opid];
        live_bytes += g.tensors[op.output].bytes();
        let step = if acc[opid].is_some() {
            live_bytes - g.tensors[op.output].bytes()
        } else {
            live_bytes
        };
        peak = peak.max(step);
        for &t in &op.inputs {
            remaining[t] -= 1;
            if remaining[t] == 0 && !is_output[t] {
                live_bytes -= g.tensors[t].bytes();
            }
        }
        if remaining[op.output] == 0 && !is_output[op.output] {
            live_bytes -= g.tensors[op.output].bytes();
        }
    }
    peak
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::graph::{DType, GraphBuilder};

    /// The Figure-1 example graph with its exact byte sizes, built from
    /// synthetic ops (sizes derived from the Appendix A tables).
    pub(crate) fn figure1_graph() -> Graph {
        let mut b = GraphBuilder::new("figure1");
        let t0 = b.input("t0", &[1568], DType::U8);
        let t1 = b.synthetic("op1", &[t0], 3136, 0);
        let t2 = b.synthetic("op2", &[t1], 1568, 0);
        let t3 = b.synthetic("op3", &[t2], 512, 0);
        let t4 = b.synthetic("op4", &[t1], 512, 0);
        let t5 = b.synthetic("op5", &[t3], 256, 0);
        let t6 = b.synthetic("op6", &[t4], 256, 0);
        let t7 = b.synthetic("op7", &[t5, t6], 512, 0);
        b.output(t7);
        b.finish().unwrap()
    }

    #[test]
    fn figure2_default_order_peak_5216() {
        let g = figure1_graph();
        let trace = simulate(&g, &g.default_order());
        // Appendix A, Figure 2: usage per step.
        let expected = [4704, 4704, 5216, 4160, 1280, 1024, 1024];
        let got: Vec<usize> = trace.steps.iter().map(|s| s.bytes).collect();
        assert_eq!(got, expected);
        assert_eq!(trace.peak_bytes, 5216);
        assert_eq!(trace.peak_step, 2); // operator #3
    }

    #[test]
    fn figure3_optimised_order_peak_4960() {
        let g = figure1_graph();
        // Paper's optimised order 1,4,6,2,3,5,7 (1-based) → 0-based op ids.
        let order = [0, 3, 5, 1, 2, 4, 6];
        let trace = simulate(&g, &order);
        let expected = [4704, 3648, 3904, 4960, 2336, 1024, 1024];
        let got: Vec<usize> = trace.steps.iter().map(|s| s.bytes).collect();
        assert_eq!(got, expected);
        assert_eq!(trace.peak_bytes, 4960);
        assert_eq!(trace.peak_step, 3); // operator #2
    }

    #[test]
    fn peak_of_matches_simulate() {
        let g = figure1_graph();
        for order in [vec![0, 1, 2, 3, 4, 5, 6], vec![0, 3, 5, 1, 2, 4, 6]] {
            assert_eq!(peak_of(&g, &order), simulate(&g, &order).peak_bytes);
        }
    }

    #[test]
    #[should_panic(expected = "invalid execution order")]
    fn simulate_rejects_invalid_order() {
        let g = figure1_graph();
        simulate(&g, &[6, 5, 4, 3, 2, 1, 0]);
    }

    #[test]
    fn residual_tensors_counted_in_figure2_step3() {
        let g = figure1_graph();
        let trace = simulate(&g, &g.default_order());
        // During op #3 (index 2) the resident set is {t1, t2, t3} —
        // t1 (output of op1) is held back for op4.
        let step = &trace.steps[2];
        let names: Vec<&str> =
            step.resident.iter().map(|&t| g.tensors[t].name.as_str()).collect();
        assert_eq!(names, vec!["op1", "op2", "op3"]);
    }

    #[test]
    fn graph_outputs_stay_resident() {
        // x -> a -> b, both a and b are outputs: a must not be freed.
        let mut bld = GraphBuilder::new("t");
        let x = bld.input("x", &[100], DType::U8);
        let a = bld.synthetic("a", &[x], 100, 0);
        let b = bld.synthetic("b", &[a], 100, 0);
        bld.output(a);
        bld.output(b);
        let g = bld.finish().unwrap();
        let trace = simulate(&g, &[0, 1]);
        assert_eq!(trace.steps[1].resident.len(), 2); // a and b
    }

    #[test]
    fn render_table_mentions_peak() {
        let g = figure1_graph();
        let trace = simulate(&g, &g.default_order());
        let table = trace.render_table(&g);
        assert!(table.contains("5216"));
        assert!(table.contains("(peak)"));
    }
}

//! Exhaustive enumeration of all topological orders.
//!
//! Backtracking over the ready set (the classic Knuth–Szwarcfiter
//! arrangement generator [32]); for each complete order the peak working
//! set is computed incrementally. Exponential — usable up to ~12 operators —
//! and kept as the ground truth the DP and B&B schedulers are property-
//! tested against.

use super::Schedule;
use crate::graph::Graph;

/// Result of the exhaustive search.
#[derive(Clone, Debug)]
pub struct BruteForceResult {
    /// A minimal-peak schedule.
    pub best: Schedule,
    /// A maximal-peak schedule (how bad the worst order is).
    pub worst: Schedule,
    /// Number of distinct topological orders enumerated.
    pub orders_enumerated: u64,
}

/// Enumerate every topological order of `g` (up to `cap`). Returns `None`
/// when the cap is exceeded. Used by tests that need to evaluate a custom
/// objective over the full order space.
pub fn all_orders(g: &Graph, cap: usize) -> Option<Vec<Vec<usize>>> {
    g.validate().ok()?;
    let n_ops = g.ops.len();
    let mut waiting = vec![0usize; n_ops];
    for op in &g.ops {
        waiting[op.id] = op.inputs.iter().filter(|&&t| g.tensors[t].producer.is_some()).count();
    }
    let mut orders = Vec::new();
    let mut order = Vec::with_capacity(n_ops);
    let mut executed = vec![false; n_ops];
    fn rec(
        g: &Graph,
        order: &mut Vec<usize>,
        waiting: &mut Vec<usize>,
        executed: &mut Vec<bool>,
        orders: &mut Vec<Vec<usize>>,
        cap: usize,
    ) -> bool {
        if order.len() == g.ops.len() {
            if orders.len() >= cap {
                return false;
            }
            orders.push(order.clone());
            return true;
        }
        for o in 0..g.ops.len() {
            if executed[o] || waiting[o] != 0 {
                continue;
            }
            executed[o] = true;
            order.push(o);
            let out = g.ops[o].output;
            for &c in &g.tensors[out].consumers {
                if g.ops[c].inputs.contains(&out) {
                    waiting[c] -= 1;
                }
            }
            let ok = rec(g, order, waiting, executed, orders, cap);
            for &c in &g.tensors[out].consumers {
                if g.ops[c].inputs.contains(&out) {
                    waiting[c] += 1;
                }
            }
            order.pop();
            executed[o] = false;
            if !ok {
                return false;
            }
        }
        true
    }
    rec(g, &mut order, &mut waiting, &mut executed, &mut orders, cap).then_some(orders)
}

/// Enumerate every topological order of `g` (up to `max_orders`), tracking
/// best and worst peak memory. Returns `None` if the cap was hit.
pub fn bruteforce(g: &Graph, max_orders: usize) -> Option<BruteForceResult> {
    g.validate().ok()?;
    let n_ops = g.ops.len();
    let n_t = g.tensors.len();

    let bytes: Vec<usize> = g.tensors.iter().map(|t| t.bytes()).collect();
    let mut is_output = vec![false; n_t];
    for &t in &g.outputs {
        is_output[t] = true;
    }
    let mut remaining = vec![0u32; n_t];
    for op in &g.ops {
        for &t in &op.inputs {
            remaining[t] += 1;
        }
    }
    let mut waiting = vec![0usize; n_ops];
    for op in &g.ops {
        waiting[op.id] = op.inputs.iter().filter(|&&t| g.tensors[t].producer.is_some()).count();
    }

    struct St<'g> {
        g: &'g Graph,
        bytes: Vec<usize>,
        is_output: Vec<bool>,
        best: Option<(usize, Vec<usize>)>,
        worst: Option<(usize, Vec<usize>)>,
        count: u64,
        cap: u64,
        capped: bool,
    }

    fn rec(
        s: &mut St,
        order: &mut Vec<usize>,
        waiting: &mut Vec<usize>,
        remaining: &mut Vec<u32>,
        executed: &mut Vec<bool>,
        live: usize,
        peak: usize,
    ) {
        if s.capped {
            return;
        }
        if order.len() == s.g.ops.len() {
            s.count += 1;
            if s.count > s.cap {
                s.capped = true;
                return;
            }
            if s.best.as_ref().map_or(true, |(b, _)| peak < *b) {
                s.best = Some((peak, order.clone()));
            }
            if s.worst.as_ref().map_or(true, |(w, _)| peak > *w) {
                s.worst = Some((peak, order.clone()));
            }
            return;
        }
        for o in 0..s.g.ops.len() {
            if executed[o] || waiting[o] != 0 {
                continue;
            }
            let op = &s.g.ops[o];
            let out = op.output;
            let step_live = live + s.bytes[out];
            let new_peak = peak.max(step_live);
            let mut after = step_live;
            for &t in &op.inputs {
                remaining[t] -= 1;
                if remaining[t] == 0 && !s.is_output[t] {
                    after -= s.bytes[t];
                }
            }
            if remaining[out] == 0 && !s.is_output[out] {
                after -= s.bytes[out];
            }
            executed[o] = true;
            order.push(o);
            for &c in &s.g.tensors[out].consumers {
                if s.g.ops[c].inputs.contains(&out) {
                    waiting[c] -= 1;
                }
            }

            rec(s, order, waiting, remaining, executed, after, new_peak);

            for &c in &s.g.tensors[out].consumers {
                if s.g.ops[c].inputs.contains(&out) {
                    waiting[c] += 1;
                }
            }
            order.pop();
            executed[o] = false;
            for &t in &op.inputs {
                remaining[t] += 1;
            }
        }
    }

    let live0: usize = g.inputs.iter().map(|&t| g.tensors[t].bytes()).sum();
    let mut st = St {
        g,
        bytes,
        is_output,
        best: None,
        worst: None,
        count: 0,
        cap: max_orders as u64,
        capped: false,
    };
    let mut order = Vec::with_capacity(n_ops);
    let mut executed = vec![false; n_ops];
    rec(&mut st, &mut order, &mut waiting, &mut remaining, &mut executed, live0, live0);
    if st.capped {
        return None;
    }
    let (bp, bo) = st.best?;
    let (wp, wo) = st.worst?;
    Some(BruteForceResult {
        best: Schedule { order: bo, peak_bytes: bp },
        worst: Schedule { order: wo, peak_bytes: wp },
        orders_enumerated: st.count,
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::graph::{DType, Graph, GraphBuilder};
    use crate::sched::{peak_of, simulate};
    use crate::util::rng::Rng;

    /// Random single-output DAG: `n_ops` synthetic operators, each consuming
    /// 1–2 earlier tensors; all sink tensors become outputs (so every op is
    /// schedulable by the backward DP).
    pub(crate) fn random_dag(rng: &mut Rng, n_ops: usize) -> Graph {
        let mut b = GraphBuilder::new("rand");
        let mut tensors = vec![b.input("x", &[64 * (1 + rng.range(0, 8))], DType::U8)];
        for i in 0..n_ops {
            let n_in = if tensors.len() >= 2 && rng.chance(0.4) { 2 } else { 1 };
            let mut ins = Vec::new();
            while ins.len() < n_in {
                let t = *rng.pick(&tensors);
                if !ins.contains(&t) {
                    ins.push(t);
                }
            }
            let bytesz = 32 * (1 + rng.range(0, 64));
            tensors.push(b.synthetic(&format!("op{i}"), &ins, bytesz, 0));
        }
        // Every tensor without consumers becomes a graph output.
        let g = b.graph();
        let sinks: Vec<usize> = g
            .tensors
            .iter()
            .filter(|t| t.consumers.is_empty() && !t.is_weight)
            .map(|t| t.id)
            .collect();
        for s in sinks {
            b.output(s);
        }
        b.finish().unwrap()
    }

    #[test]
    fn enumerates_figure1_orders() {
        let g = crate::sched::tests::figure1_graph();
        let r = bruteforce(&g, usize::MAX).unwrap();
        assert_eq!(r.best.peak_bytes, 4960);
        assert_eq!(r.worst.peak_bytes >= r.best.peak_bytes, true);
        // Figure-1 graph: orders = interleavings of the two branches with
        // the concat last. Branch A = ops 2,3,5 after 1; branch B = 4,6.
        // Count must be C(5,2) = 10.
        assert_eq!(r.orders_enumerated, 10);
    }

    #[test]
    fn cap_returns_none() {
        let g = crate::sched::tests::figure1_graph();
        assert!(bruteforce(&g, 3).is_none());
    }

    #[test]
    fn best_and_worst_orders_are_valid() {
        let mut rng = Rng::new(123);
        for _ in 0..10 {
            let g = random_dag(&mut rng, 6);
            let r = bruteforce(&g, usize::MAX).unwrap();
            g.check_order(&r.best.order).unwrap();
            g.check_order(&r.worst.order).unwrap();
            assert_eq!(peak_of(&g, &r.best.order), r.best.peak_bytes);
            assert_eq!(simulate(&g, &r.worst.order).peak_bytes, r.worst.peak_bytes);
            assert!(r.best.peak_bytes <= r.worst.peak_bytes);
        }
    }

    #[test]
    fn linear_chain_has_one_order() {
        let mut b = GraphBuilder::new("chain");
        let mut t = b.input("x", &[16], DType::U8);
        for i in 0..5 {
            t = b.synthetic(&format!("s{i}"), &[t], 16, 0);
        }
        b.output(t);
        let g: Graph = b.finish().unwrap();
        let r = bruteforce(&g, usize::MAX).unwrap();
        assert_eq!(r.orders_enumerated, 1);
        assert_eq!(r.best.peak_bytes, r.worst.peak_bytes);
    }
}

//! Greedy scheduling heuristics.
//!
//! Neither is optimal in general (the property tests include graphs where
//! they lose to Algorithm 1), but both are linear-ish and serve as (a) the
//! incumbent for branch-and-bound pruning and (b) baselines in the
//! scheduler ablation bench.

use super::Schedule;
use crate::graph::{Graph, OpId};

/// Pick, at every step, the ready operator whose execution step needs the
/// least memory (live bytes + output), breaking ties toward the op that
/// frees the most bytes, then by id (deterministic).
pub fn greedy_min_increase(g: &Graph) -> Schedule {
    let n_t = g.tensors.len();
    let bytes: Vec<usize> = g.tensors.iter().map(|t| t.bytes()).collect();
    // Join-elided slices write through their accumulator's buffer, so
    // their output adds no bytes at its own step (live tracking still
    // carries the full size; the accumulator dies at the same step).
    let discount: Vec<usize> = g
        .ops
        .iter()
        .zip(super::elided_accumulators(g))
        .map(|(op, acc)| if acc.is_some() { bytes[op.output] } else { 0 })
        .collect();
    let mut is_output = vec![false; n_t];
    for &t in &g.outputs {
        is_output[t] = true;
    }
    let mut remaining = vec![0u32; n_t];
    for op in &g.ops {
        for &t in &op.inputs {
            remaining[t] += 1;
        }
    }
    let mut waiting: Vec<usize> = g
        .ops
        .iter()
        .map(|op| op.inputs.iter().filter(|&&t| g.tensors[t].producer.is_some()).count())
        .collect();
    let mut executed = vec![false; g.ops.len()];
    let mut live: usize = g.inputs.iter().map(|&t| bytes[t]).sum();
    let mut peak = live;
    let mut order = Vec::with_capacity(g.ops.len());

    for _ in 0..g.ops.len() {
        // Evaluate each ready op: step cost and bytes freed.
        let mut best: Option<(usize, isize, OpId)> = None;
        for o in 0..g.ops.len() {
            if executed[o] || waiting[o] != 0 {
                continue;
            }
            let op = &g.ops[o];
            let step = live + bytes[op.output] - discount[o];
            let mut freed: isize = 0;
            for &t in &op.inputs {
                if remaining[t] == 1 && !is_output[t] {
                    freed += bytes[t] as isize;
                }
            }
            let key = (step, -freed, o);
            if best.map_or(true, |(bs, bf, bo)| key < (bs, bf, bo)) {
                best = Some(key);
            }
        }
        let (_, _, o) = best.expect("greedy: no ready op (cyclic graph?)");
        let op = &g.ops[o];
        live += bytes[op.output];
        peak = peak.max(live - discount[o]);
        for &t in &op.inputs {
            remaining[t] -= 1;
            if remaining[t] == 0 && !is_output[t] {
                live -= bytes[t];
            }
        }
        if remaining[op.output] == 0 && !is_output[op.output] {
            live -= bytes[op.output];
        }
        executed[o] = true;
        order.push(o);
        for &c in &g.tensors[op.output].consumers {
            if g.ops[c].inputs.contains(&op.output) {
                waiting[c] -= 1;
            }
        }
    }
    Schedule { order, peak_bytes: peak }
}

/// Depth-first branch completion: always continue the most recently opened
/// branch (run the consumer of the most recently produced tensor when
/// ready). This mimics what a naive converter that walks the graph
/// depth-first would emit.
pub fn greedy_depth_first(g: &Graph) -> Schedule {
    let n_t = g.tensors.len();
    let mut remaining = vec![0u32; n_t];
    for op in &g.ops {
        for &t in &op.inputs {
            remaining[t] += 1;
        }
    }
    let mut waiting: Vec<usize> = g
        .ops
        .iter()
        .map(|op| op.inputs.iter().filter(|&&t| g.tensors[t].producer.is_some()).count())
        .collect();
    let mut executed = vec![false; g.ops.len()];
    let mut order = Vec::with_capacity(g.ops.len());
    // Stack of candidate ops; seeded with ops ready at the start, lowest id
    // on top.
    let mut stack: Vec<OpId> = (0..g.ops.len()).rev().filter(|&o| waiting[o] == 0).collect();

    while order.len() < g.ops.len() {
        let o = loop {
            match stack.pop() {
                Some(o) if !executed[o] && waiting[o] == 0 => break o,
                Some(_) => continue,
                None => {
                    // Shouldn't happen for valid DAGs, but fall back to any
                    // ready op for robustness.
                    let o = (0..g.ops.len())
                        .find(|&o| !executed[o] && waiting[o] == 0)
                        .expect("depth-first: no ready op");
                    break o;
                }
            }
        };
        let op = &g.ops[o];
        executed[o] = true;
        order.push(o);
        for &t in &op.inputs {
            remaining[t] -= 1;
        }
        // Push newly-ready consumers of the fresh output (highest priority).
        let mut newly: Vec<OpId> = Vec::new();
        for &c in &g.tensors[op.output].consumers {
            if g.ops[c].inputs.contains(&op.output) {
                waiting[c] -= 1;
                if waiting[c] == 0 {
                    newly.push(c);
                }
            }
        }
        newly.sort_unstable_by(|a, b| b.cmp(a));
        stack.extend(newly);
    }
    let peak = super::peak_of(g, &order);
    Schedule { order, peak_bytes: peak }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::tests::figure1_graph;
    use crate::sched::{bruteforce, optimal};
    use crate::util::prop;

    #[test]
    fn greedy_orders_are_valid() {
        let g = figure1_graph();
        for s in [greedy_min_increase(&g), greedy_depth_first(&g)] {
            g.check_order(&s.order).unwrap();
            assert_eq!(crate::sched::peak_of(&g, &s.order), s.peak_bytes);
        }
    }

    #[test]
    fn greedy_at_least_matches_worst_case() {
        prop::check_sized("greedy<=worst", 40, 3, 8, |rng, n| {
            let g = crate::sched::bruteforce::tests::random_dag(rng, n);
            let bf = bruteforce(&g, usize::MAX).unwrap();
            let gm = greedy_min_increase(&g);
            assert!(gm.peak_bytes >= bf.best.peak_bytes);
            assert!(gm.peak_bytes <= bf.worst.peak_bytes);
        });
    }

    #[test]
    fn greedy_is_not_always_optimal() {
        // Find (by seeded search) at least one graph where greedy
        // min-increase is strictly worse than Algorithm 1 — documents that
        // the DP is actually needed.
        let mut found = false;
        let mut rng = crate::util::rng::Rng::new(0x5EED);
        for _ in 0..400 {
            let g = crate::sched::bruteforce::tests::random_dag(&mut rng, 8);
            let gm = greedy_min_increase(&g);
            let (opt, _) = optimal(&g).unwrap();
            assert!(gm.peak_bytes >= opt.peak_bytes);
            if gm.peak_bytes > opt.peak_bytes {
                found = true;
                break;
            }
        }
        assert!(found, "expected at least one graph where greedy is suboptimal");
    }

    #[test]
    fn depth_first_completes_branches() {
        let g = figure1_graph();
        let s = greedy_depth_first(&g);
        // Depth-first from op1 runs branch ops 2→3→5 before 4→6 (0-based:
        // 1,2,4 before 3,5), then the concat.
        assert_eq!(s.order, vec![0, 1, 2, 4, 3, 5, 6]);
    }
}

//! AOT code generation: lower a verified [`OptimizeReport`] into a
//! freestanding, dependency-free C99 artifact with the plan baked in.
//!
//! The interpreter ([`crate::interp`]) executes a schedule by dispatching
//! on `OpKind` at runtime; this backend removes the dispatch entirely. For
//! each scheduled operator it emits one specialized C function whose loop
//! bounds, halo paddings, channel-band offsets, quantization multipliers
//! and arena addresses are all compile-time constants, then strings the
//! functions together in schedule order behind a single
//! `<model>_invoke(input, output)` entry point.
//!
//! Memory layout is the verified static plan: one `.bss` arena whose size
//! equals the certificate's `arena_bytes`, tensor slots as `#define`d
//! offsets into it ([`crate::alloc::StaticPlan::best_fit`]), and weights
//! as `static const` `.rodata` tables. Nothing is allocated at runtime
//! and the only libc dependencies are `memcpy` and (when the model uses
//! softmax / batch-norm / int8 rounding) `<math.h>`.
//!
//! The contract with the interpreter is *bit-exactness*: the generated
//! harness ([`Artifact::harness`]) drives the compiled artifact with the
//! audit's deterministic input and byte-compares every output against the
//! interpreter's. CI compiles every zoo model and the int8 TFLite fixture
//! with `cc -std=c99 -Wall -Werror` and runs that harness.

mod emit;

use std::collections::HashMap;

use crate::alloc::{CompactPolicy, StaticPlan};
use crate::api::OptimizeReport;
use crate::graph::{DType, Graph, OpId, OpKind};
use crate::interp::{ExecConfig, Interpreter, TensorData, WeightStore};
use crate::trace::audit;
use crate::util::error::{anyhow, bail, Result};

use emit::{Ctx, Cw, Helpers};

/// A generated C artifact plus the metadata front-ends report on.
pub struct Artifact {
    /// Sanitized C identifier prefix (`<symbol>_invoke`, `<symbol>_arena`).
    pub symbol: String,
    /// File name the source `#include`s the header by (`<symbol>.h`).
    pub header_name: String,
    /// Public header: arena/io sizes and the `invoke` prototype.
    pub header: String,
    /// The model: weights, arena, one function per scheduled op, `invoke`.
    pub source: String,
    /// Standalone golden-equivalence `main`: feeds the audit input and
    /// byte-compares the output against the interpreter's (exit 0/1).
    pub harness: String,
    /// Activation dtype label (`f32` / `i8` / `u8`).
    pub dtype: &'static str,
    /// Declared size of the static arena — equals the certificate's.
    pub arena_bytes: usize,
    /// Scheduler's analytic peak from the certificate.
    pub peak_bytes: usize,
    /// Total bytes of emitted `static const` weight tables.
    pub rodata_bytes: usize,
    /// Scheduled operator count (= emitted step functions).
    pub n_ops: usize,
    pub input_elems: usize,
    pub output_elems: usize,
}

impl Artifact {
    /// The source with the header inlined in place of its `#include` —
    /// a single self-contained `.c` file (what `plan-serve` ships).
    pub fn single_file(&self) -> String {
        let inc = format!("#include \"{}\"\n", self.header_name);
        self.source.replacen(&inc, &self.header, 1)
    }
}

/// Reduce `name` to a C identifier: alphanumerics pass through
/// (lowercased), everything else becomes `_`, and a leading digit gets an
/// `m` prefix so `7seg.tflite` still yields a legal symbol.
pub fn sanitize_symbol(name: &str) -> String {
    let mut s = String::with_capacity(name.len());
    for ch in name.chars() {
        if ch.is_ascii_alphanumeric() {
            s.push(ch.to_ascii_lowercase());
        } else {
            s.push('_');
        }
    }
    if s.is_empty() || s.starts_with(|c: char| c.is_ascii_digit()) {
        s.insert(0, 'm');
    }
    s
}

/// The weight store matching `report`'s graph: the imported store for
/// `.tflite` sources, the zoo preparation at the graph's dtype otherwise.
pub fn weights_for_report(report: &OptimizeReport) -> Result<WeightStore> {
    if let Some(src) = &report.tflite {
        return Ok(src.imported.weights.clone());
    }
    let want = dtype_label(report.graph.tensors[report.graph.inputs[0]].dtype)?;
    let prepared = audit::prepare_zoo(&report.model).map_err(|e| anyhow!("{e}"))?;
    prepared
        .into_iter()
        .find(|p| p.dtype == want)
        .map(|p| p.ws)
        .ok_or_else(|| anyhow!("no {want} weights prepared for zoo model {}", report.model))
}

fn dtype_label(d: DType) -> Result<&'static str> {
    Ok(match d {
        DType::F32 => "f32",
        DType::I8 => "i8",
        DType::U8 => "u8",
        DType::I32 => bail!("i32 activations are not a supported codegen dtype"),
    })
}

/// Lower `report` (with its weights) into a C artifact named `symbol`.
///
/// Re-runs the independent verifier first — codegen refuses to emit a
/// plan it cannot certify — and asserts the emitted arena size equals the
/// certificate's before returning.
pub fn generate(report: &OptimizeReport, ws: &WeightStore, symbol: &str) -> Result<Artifact> {
    let cert = crate::verify::certify_report(report).map_err(|e| anyhow!("verify: {e}"))?;

    // The deployed plan: the split twin when a split search committed one,
    // the reorder-only optimum otherwise.
    let (g, order, ws_final): (&Graph, Vec<OpId>, WeightStore) = match &report.split {
        Some(s) => (
            &s.outcome.graph,
            s.outcome.schedule.order.clone(),
            s.outcome.remap_weights(ws),
        ),
        None => (&report.graph, report.reordered.order.clone(), ws.clone()),
    };

    if g.inputs.len() != 1 || g.outputs.len() != 1 {
        bail!(
            "codegen supports single-input/single-output graphs ({} has {} inputs, {} outputs)",
            report.model,
            g.inputs.len(),
            g.outputs.len()
        );
    }
    let dtype = g.tensors[g.inputs[0]].dtype;
    let dlabel = dtype_label(dtype)?;
    for t in &g.tensors {
        if !t.is_weight && t.dtype != dtype {
            bail!(
                "mixed activation dtypes ({} is {}, input is {})",
                t.name,
                t.dtype.name(),
                dtype.name()
            );
        }
    }
    let esize = dtype.size();

    // The static layout. `certify_report` independently recomputes and
    // checks this same plan, so equality here means the emitted `#define`s
    // carry *certified* offsets, not merely recomputed ones.
    let plan = StaticPlan::best_fit(g, &order);
    if plan.arena_bytes != cert.arena_bytes {
        bail!(
            "arena mismatch: best-fit plan wants {} B, certificate says {} B",
            plan.arena_bytes,
            cert.arena_bytes
        );
    }
    if plan.arena_bytes % esize != 0 {
        bail!("arena size {} not a multiple of element size {esize}", plan.arena_bytes);
    }
    let mut off: HashMap<usize, usize> = HashMap::new();
    for (&tid, &byte_off) in &plan.offsets {
        if byte_off % esize != 0 {
            bail!("tensor t{tid} offset {byte_off} not a multiple of element size {esize}");
        }
        off.insert(tid, byte_off / esize);
    }
    // `PartialInto` writes its band straight through the accumulator slot;
    // the emitter skips the interpreter's copy-accumulator step on the
    // strength of this aliasing, so prove it holds.
    for op in &g.ops {
        if matches!(op.kind, OpKind::PartialInto { .. }) {
            if let Some(&acc) = op.inputs.get(1) {
                if off.get(&op.output) != off.get(&acc) {
                    bail!(
                        "{}: PartialInto output t{} does not alias accumulator t{acc}",
                        op.name,
                        op.output
                    );
                }
            }
        }
    }

    let sym = sanitize_symbol(symbol);
    let cx = Ctx { sym: sym.clone(), g, ws: &ws_final, off, dtype };

    // Phase 1: lower every scheduled op, recording which shared helpers
    // the steps actually reference.
    let mut h = Helpers::default();
    let mut steps = String::new();
    for (i, &oid) in order.iter().enumerate() {
        steps.push_str(&emit::emit_step(&cx, i, &g.ops[oid], &mut h)?);
        steps.push('\n');
    }

    // Phase 2: assemble the translation unit around them.
    let (weights_c, rodata_bytes) = render_weights(&cx, &order)?;
    let input_elems = g.tensors[g.inputs[0]].elems();
    let output_elems = g.tensors[g.outputs[0]].elems();

    let header_name = format!("{sym}.h");
    let header = render_header(
        &sym,
        dtype,
        plan.arena_bytes,
        cert.peak_bytes,
        rodata_bytes,
        input_elems,
        output_elems,
    );
    let source = render_source(&cx, report, &header_name, &h, &weights_c, &steps, &plan, &order);
    let harness = render_harness(&cx, g, &order, &ws_final, plan.arena_bytes)?;

    Ok(Artifact {
        symbol: sym,
        header_name,
        header,
        source,
        harness,
        dtype: dlabel,
        arena_bytes: plan.arena_bytes,
        peak_bytes: cert.peak_bytes,
        rodata_bytes,
        n_ops: order.len(),
        input_elems,
        output_elems,
    })
}

/// `static const` tables for every weight tensor a scheduled op touches,
/// in tensor-id order. Returns the C text and the total `.rodata` bytes.
fn render_weights(cx: &Ctx, order: &[OpId]) -> Result<(String, usize)> {
    let mut tids: Vec<usize> = Vec::new();
    for &oid in order {
        let op = &cx.g.ops[oid];
        for &t in op.weights.iter().chain(op.inputs.iter()) {
            if cx.g.tensors[t].is_weight && !tids.contains(&t) {
                tids.push(t);
            }
        }
    }
    tids.sort_unstable();

    let mut out = String::new();
    let mut bytes = 0usize;
    for t in tids {
        let data = cx
            .ws
            .data
            .get(&t)
            .ok_or_else(|| anyhow!("weight tensor t{t} ({}) has no payload", cx.g.tensors[t].name))?;
        if data.len() != cx.g.tensors[t].elems() {
            bail!(
                "weight tensor t{t} payload has {} elements, shape wants {}",
                data.len(),
                cx.g.tensors[t].elems()
            );
        }
        let (cty, esz) = match data {
            TensorData::F32(_) => ("float", 4),
            TensorData::I8(_) => ("int8_t", 1),
            TensorData::I32(_) => ("int32_t", 4),
            TensorData::U8(_) => ("uint8_t", 1),
        };
        bytes += data.len() * esz;
        out.push_str(&format!(
            "/* {} {:?} */\nstatic const {cty} {}[{}] = {{\n",
            cx.g.tensors[t].name,
            cx.g.tensors[t].shape,
            cx.w(t),
            data.len()
        ));
        let mut line = String::from("   ");
        let mut push = |line: &mut String, out: &mut String, lit: String| {
            line.push(' ');
            line.push_str(&lit);
            line.push(',');
            if line.len() >= 96 {
                out.push_str(line);
                out.push('\n');
                line.clear();
                line.push_str("   ");
            }
        };
        match data {
            TensorData::F32(v) => {
                for &x in v {
                    push(&mut line, &mut out, emit::c_f32(x)?);
                }
            }
            TensorData::I8(v) => {
                for &x in v {
                    push(&mut line, &mut out, x.to_string());
                }
            }
            TensorData::I32(v) => {
                for &x in v {
                    push(&mut line, &mut out, x.to_string());
                }
            }
            TensorData::U8(v) => {
                for &x in v {
                    push(&mut line, &mut out, x.to_string());
                }
            }
        }
        if !line.trim().is_empty() {
            out.push_str(&line);
            out.push('\n');
        }
        out.push_str("};\n\n");
    }
    Ok((out, bytes))
}

fn render_header(
    sym: &str,
    dtype: DType,
    arena_bytes: usize,
    peak_bytes: usize,
    rodata_bytes: usize,
    input_elems: usize,
    output_elems: usize,
) -> String {
    let up = sym.to_ascii_uppercase();
    let ety = match dtype {
        DType::F32 => "float",
        DType::I8 => "int8_t",
        DType::U8 => "uint8_t",
        DType::I32 => "int32_t",
    };
    let mut w = Cw::new();
    w.l(format!("/* {sym}: generated by mcu-reorder codegen -- do not edit. */"));
    w.l(format!("#ifndef {up}_H"));
    w.l(format!("#define {up}_H"));
    w.l("");
    w.l("#include <stdint.h>");
    w.l("");
    w.l("/* Static activation arena, sized to the certified plan peak. */");
    w.l(format!("#define {up}_ARENA_BYTES {arena_bytes}u"));
    w.l(format!("#define {up}_PEAK_BYTES {peak_bytes}u"));
    w.l(format!("#define {up}_RODATA_BYTES {rodata_bytes}u"));
    w.l(format!("#define {up}_INPUT_ELEMS {input_elems}u"));
    w.l(format!("#define {up}_OUTPUT_ELEMS {output_elems}u"));
    w.l("");
    w.l(format!("/* One inference: reads input[{up}_INPUT_ELEMS], writes"));
    w.l(format!(" * output[{up}_OUTPUT_ELEMS]. Not reentrant (static arena). */"));
    w.l(format!("void {sym}_invoke(const {ety} *input, {ety} *output);"));
    w.l("");
    w.l(format!("#endif /* {up}_H */"));
    w.finish()
}

#[allow(clippy::too_many_arguments)]
fn render_source(
    cx: &Ctx,
    report: &OptimizeReport,
    header_name: &str,
    h: &Helpers,
    weights_c: &str,
    steps: &str,
    plan: &StaticPlan,
    order: &[OpId],
) -> String {
    let sym = &cx.sym;
    let g = cx.g;
    let ety = cx.ety();
    let esize = cx.dtype.size();
    let mut w = Cw::new();
    w.l(format!(
        "/* Model `{}` ({} scheduled ops, {} activations) lowered by",
        report.model,
        order.len(),
        cx.dtype.name()
    ));
    w.l(" * mcu-reorder codegen. Operator order and arena offsets are the");
    w.l(" * verified plan; edit the model, not this file. */");
    w.l("");
    w.l(format!("#include \"{header_name}\""));
    w.l("");
    w.l("#include <string.h>");
    if h.math {
        w.l("#include <math.h>");
    }
    w.l("");

    if h.sat_i32_f {
        w.l("/* f32 -> i32 cast with Rust `as` semantics (saturating, NaN -> 0). */");
        w.open(format!("static int32_t {sym}_sat_i32_f(float v) {{"));
        w.l("if (v != v) return 0;");
        w.l("if (v >= 2147483648.0f) return INT32_MAX;");
        w.l("if (v < -2147483648.0f) return INT32_MIN;");
        w.l("return (int32_t)v;");
        w.close();
        w.l("");
    }
    if h.sat_i32_d {
        w.l("/* f64 -> i32 cast with Rust `as` semantics (saturating, NaN -> 0). */");
        w.open(format!("static int32_t {sym}_sat_i32_d(double v) {{"));
        w.l("if (v != v) return 0;");
        w.l("if (v >= 2147483648.0) return INT32_MAX;");
        w.l("if (v < -2147483648.0) return INT32_MIN;");
        w.l("return (int32_t)v;");
        w.close();
        w.l("");
    }
    if h.requant {
        w.l("/* Fixed-point requantization: round-half-up multiply-shift with the");
        w.l(" * normalized multiplier baked in at generation time (interp::quant). */");
        w.open(format!(
            "static int8_t {sym}_requant(int32_t acc, int64_t mult, int shift, int32_t zp) {{"
        ));
        w.l("int64_t prod = (int64_t)acc * mult;");
        w.l("int32_t v = (int32_t)((prod + ((int64_t)1 << (shift - 1))) >> shift) + zp;");
        w.l("if (v < -128) v = -128;");
        w.l("if (v > 127) v = 127;");
        w.l("return (int8_t)v;");
        w.close();
        w.l("");
    }

    let mut s = w.finish();
    if !weights_c.is_empty() {
        s.push_str("/* -------- weights (.rodata) -------- */\n\n");
        s.push_str(weights_c);
    }

    s.push_str("/* -------- activation arena (.bss) -------- */\n\n");
    let mut w = Cw::new();
    w.l(format!("static {ety} {sym}_arena[{}];", plan.arena_bytes / esize));
    w.l("");
    let mut tids: Vec<usize> = cx.off.keys().copied().collect();
    tids.sort_unstable();
    for t in tids {
        w.l(format!(
            "#define {} ({sym}_arena + {}) /* {} {:?} */",
            cx.t(t),
            cx.off[&t],
            g.tensors[t].name,
            g.tensors[t].shape
        ));
    }
    w.l("");
    s.push_str(&w.finish());

    s.push_str(steps);

    let mut w = Cw::new();
    let in_t = g.inputs[0];
    let out_t = g.outputs[0];
    w.open(format!("void {sym}_invoke(const {ety} *input, {ety} *output) {{"));
    w.l(format!(
        "memcpy({}, input, {}u);",
        cx.t(in_t),
        g.tensors[in_t].elems() * esize
    ));
    for i in 0..order.len() {
        w.l(format!("{sym}_step{i}();"));
    }
    w.l(format!(
        "memcpy(output, {}, {}u);",
        cx.t(out_t),
        g.tensors[out_t].elems() * esize
    ));
    w.close();
    s.push_str(&w.finish());
    s
}

/// Standalone `main` asserting bit-exact equivalence with the interpreter
/// run at the same schedule: compile-time arena-size check, audit input
/// baked in as bytes, byte-for-byte output compare.
fn render_harness(
    cx: &Ctx,
    g: &Graph,
    order: &[OpId],
    ws: &WeightStore,
    arena_bytes: usize,
) -> Result<String> {
    let inputs = audit::inputs_for(g, ws).map_err(|e| anyhow!("{e}"))?;
    let interp = Interpreter::new(
        g,
        ws.clone(),
        ExecConfig {
            arena_bytes: 1 << 24,
            policy: CompactPolicy::EveryOp,
            order: Some(order.to_vec()),
        },
    );
    let result = interp.run(&inputs).map_err(|e| anyhow!("interpreter: {e}"))?;
    let input_bytes = inputs[0].to_bytes();
    let expected = result.outputs[0].to_bytes();

    let sym = &cx.sym;
    let up = sym.to_ascii_uppercase();
    let ety = cx.ety();
    let mut w = Cw::new();
    w.l(format!("/* Golden-equivalence harness for `{sym}`: feeds the audit's"));
    w.l(" * deterministic input and byte-compares the output against the Rust");
    w.l(" * interpreter's (baked in below). Exit 0 on exact match. */");
    w.l("");
    w.l("#include <stdio.h>");
    w.l("#include <string.h>");
    w.l("");
    w.l(format!("#include \"{sym}.h\""));
    w.l("");
    w.l("/* The artifact must declare exactly the certified arena size. */");
    w.l(format!(
        "typedef char {sym}_arena_size_check[({up}_ARENA_BYTES == {arena_bytes}u) ? 1 : -1];"
    ));
    w.l("");
    let mut s = w.finish();
    s.push_str(&render_byte_array(&format!("{sym}_input_bytes"), &input_bytes));
    s.push('\n');
    s.push_str(&render_byte_array(&format!("{sym}_expected_bytes"), &expected));
    s.push('\n');

    let mut w = Cw::new();
    w.open("int main(void) {");
    w.l(format!("static {ety} in[{up}_INPUT_ELEMS];"));
    w.l(format!("static {ety} out[{up}_OUTPUT_ELEMS];"));
    w.l(format!("memcpy(in, {sym}_input_bytes, sizeof in);"));
    w.l(format!("{sym}_invoke(in, out);"));
    w.l("const unsigned char *got = (const unsigned char *)out;");
    w.open(format!("for (unsigned i = 0; i < sizeof {sym}_expected_bytes; i++) {{"));
    w.open(format!("if (got[i] != {sym}_expected_bytes[i]) {{"));
    w.l(format!(
        "fprintf(stderr, \"{sym}: mismatch at byte %u: got %02x want %02x\\n\","
    ));
    w.l(format!("        i, got[i], {sym}_expected_bytes[i]);"));
    w.l("return 1;");
    w.close();
    w.close();
    w.l(format!(
        "printf(\"{sym}: OK (%u output bytes bit-exact)\\n\", (unsigned)sizeof {sym}_expected_bytes);"
    ));
    w.l("return 0;");
    w.close();
    s.push_str(&w.finish());
    Ok(s)
}

fn render_byte_array(name: &str, bytes: &[u8]) -> String {
    let mut s = format!("static const unsigned char {name}[{}] = {{\n", bytes.len());
    for chunk in bytes.chunks(16) {
        s.push_str("   ");
        for b in chunk {
            s.push_str(&format!(" {b},"));
        }
        s.push('\n');
    }
    s.push_str("};\n");
    s
}

//! Per-operator C loop emission.
//!
//! Every function here lowers one scheduled operator into a specialized,
//! freestanding C99 loop nest whose arithmetic is a *transcription* of the
//! corresponding interpreter kernel ([`crate::interp::ops`] /
//! [`crate::interp::quant`]): same accumulation order, same rounding
//! helpers, same zero-point handling, same activation clamps. Bit-exact
//! equivalence with the interpreter is the contract the generated harness
//! asserts, so any change to a kernel in `interp` must land here too.
//!
//! Band variants (`Partial` / `PartialInto`) bake the halo geometry —
//! effective padding, channel-band start, write-through offsets — into
//! compile-time constants; bounds guards are emitted only when a tap can
//! actually fall outside the input slab.

use std::collections::HashMap;

use crate::graph::{Act, DType, Graph, Op, OpKind, Padding, SplitAxis, TensorId};
use crate::interp::ops::{pad_amounts, Hwc};
use crate::interp::quant::{FixedMult, QuantParams};
use crate::interp::{band_shape_of, partial_pads, WeightStore};
use crate::util::error::{anyhow, bail, Result};

/// Emission context shared by every step emitter.
pub(crate) struct Ctx<'a> {
    pub sym: String,
    pub g: &'a Graph,
    pub ws: &'a WeightStore,
    /// Element (not byte) offsets of the non-weight tensors in the arena.
    pub off: HashMap<TensorId, usize>,
    /// Uniform activation dtype of the graph.
    pub dtype: DType,
}

impl Ctx<'_> {
    /// C element type of the activation arena.
    pub(crate) fn ety(&self) -> &'static str {
        match self.dtype {
            DType::F32 => "float",
            DType::I8 => "int8_t",
            DType::U8 => "uint8_t",
            DType::I32 => "int32_t",
        }
    }

    /// Arena-slot macro of tensor `t` (expands to `arena + offset`).
    pub(crate) fn t(&self, t: TensorId) -> String {
        format!("{}_t{}", self.sym, t)
    }

    /// Rodata array name of weight tensor `t`.
    pub(crate) fn w(&self, t: TensorId) -> String {
        format!("{}_w{}", self.sym, t)
    }

    /// Quantization parameters of tensor `t`, with the interpreter's
    /// identity default for tensors that carry none.
    pub(crate) fn qp(&self, t: TensorId) -> QuantParams {
        self.ws.qparams.get(&t).copied().unwrap_or(QuantParams { scale: 1.0, zero_point: 0 })
    }

    fn shape(&self, t: TensorId) -> &[usize] {
        &self.g.tensors[t].shape
    }

    fn elems(&self, t: TensorId) -> usize {
        self.g.tensors[t].elems()
    }
}

/// Which shared static helpers the emitted steps actually reference; the
/// preamble emits only these (the sources compile under `-Werror` with
/// `-Wall`, so an unused `static` function is a build break).
#[derive(Default)]
pub(crate) struct Helpers {
    /// Saturating f32 → i32 cast (Rust `as` semantics).
    pub sat_i32_f: bool,
    /// Saturating f64 → i32 cast.
    pub sat_i32_d: bool,
    /// Fixed-point requantization (the `FixedMult` rounding shift).
    pub requant: bool,
    /// `<math.h>` symbols used (`expf`, `sqrtf`, `roundf`, `INFINITY`…).
    pub math: bool,
}

/// Indented C writer.
pub(crate) struct Cw {
    s: String,
    ind: usize,
}

impl Cw {
    pub(crate) fn new() -> Cw {
        Cw { s: String::new(), ind: 0 }
    }

    pub(crate) fn l(&mut self, line: impl AsRef<str>) {
        let line = line.as_ref();
        if line.is_empty() {
            self.s.push('\n');
            return;
        }
        for _ in 0..self.ind {
            self.s.push_str("    ");
        }
        self.s.push_str(line);
        self.s.push('\n');
    }

    pub(crate) fn open(&mut self, line: impl AsRef<str>) {
        self.l(line);
        self.ind += 1;
    }

    pub(crate) fn close(&mut self) {
        self.ind -= 1;
        self.l("}");
    }

    pub(crate) fn finish(self) -> String {
        self.s
    }
}

/// Render an f32 constant as a C literal that `strtof`/gcc parse back to
/// the identical bit pattern (9 significant digits round-trip binary32).
pub(crate) fn c_f32(v: f32) -> Result<String> {
    if v.is_nan() {
        bail!("NaN constant in model parameters");
    }
    if v == f32::INFINITY {
        return Ok("INFINITY".into());
    }
    if v == f32::NEG_INFINITY {
        return Ok("-INFINITY".into());
    }
    Ok(format!("{v:.8e}f"))
}

/// Render an f64 constant (17 significant digits round-trip binary64).
pub(crate) fn c_f64(v: f64) -> Result<String> {
    if !v.is_finite() {
        bail!("non-finite f64 constant in quantization parameters");
    }
    Ok(format!("{v:.16e}"))
}

/// `var + k` with the `+ 0` folded away.
fn shifted(var: &str, k: usize) -> String {
    if k == 0 {
        var.to_string()
    } else {
        format!("{var} + {k}")
    }
}

/// Index expression `base * stride + tap - pad` with trivial terms folded.
fn tap_idx(base: &str, stride: usize, tap: &str, pad: isize) -> String {
    let mut s =
        if stride == 1 { base.to_string() } else { format!("{base} * {stride}") };
    s = format!("{s} + {tap}");
    match pad.cmp(&0) {
        std::cmp::Ordering::Greater => format!("{s} - {pad}"),
        std::cmp::Ordering::Less => format!("{s} + {}", -pad),
        std::cmp::Ordering::Equal => s,
    }
}

/// Destination an op (or op band) writes to: either a rectangular window
/// of a full NHWC tensor (`PartialInto` write-through at compile-time
/// offsets) or a flat range (whole ops, `Partial` bands, dense rows).
enum Dst {
    Hwc { base: String, w: usize, c: usize, ry: usize, rx: usize, rc: usize },
    Flat { base: String, off: usize },
}

impl Dst {
    /// The whole output tensor of `op` (also a `Partial` band, whose
    /// output tensor *is* the band).
    fn whole(cx: &Ctx, t: TensorId) -> Dst {
        let shape = cx.shape(t);
        if shape.len() == 4 {
            let o = Hwc::from_shape(shape);
            Dst::Hwc { base: cx.t(t), w: o.w, c: o.c, ry: 0, rx: 0, rc: 0 }
        } else {
            Dst::Flat { base: cx.t(t), off: 0 }
        }
    }

    /// The `[offset, offset+len)` band of the full join tensor `t` along
    /// `axis` — mirrors `interp::ops::write_band`'s placement rules.
    fn band(cx: &Ctx, t: TensorId, axis: SplitAxis, offset: usize) -> Dst {
        let shape = cx.shape(t);
        if shape.len() == 4 {
            let o = Hwc::from_shape(shape);
            let (ry, rx, rc) = match axis {
                SplitAxis::Rows => (offset, 0, 0),
                SplitAxis::Cols => (0, offset, 0),
                SplitAxis::Channels => (0, 0, offset),
            };
            Dst::Hwc { base: cx.t(t), w: o.w, c: o.c, ry, rx, rc }
        } else {
            Dst::Flat { base: cx.t(t), off: offset }
        }
    }

    /// Pointer expression for the channel row at band coords (`oy`,`ox`).
    fn row_ptr(&self, oy: &str, ox: &str) -> Result<String> {
        match self {
            Dst::Hwc { base, w, c, ry, rx, rc } => {
                let ye = shifted(oy, *ry);
                let xe = shifted(ox, *rx);
                let mut e = format!("{base} + (({ye}) * {w} + ({xe})) * {c}");
                if *rc > 0 {
                    e = format!("{e} + {rc}");
                }
                Ok(e)
            }
            Dst::Flat { .. } => Err(anyhow!("spatial op writing a flat destination")),
        }
    }

    /// True when the destination is a contiguous cover of a band with the
    /// given trailing dims (so elementwise ops can use one flat loop).
    fn is_flat_cover(&self, band: &[usize]) -> bool {
        match self {
            Dst::Flat { .. } => true,
            Dst::Hwc { w, c, ry, rx, rc, .. } => {
                let b = Hwc::from_shape(band);
                *ry == 0 && *rx == 0 && *rc == 0 && b.w == *w && b.c == *c
            }
        }
    }

    /// Base pointer expression for the flat-cover case.
    fn flat_ptr(&self) -> String {
        match self {
            Dst::Hwc { base, .. } => base.clone(),
            Dst::Flat { base, off } => {
                if *off == 0 {
                    base.clone()
                } else {
                    format!("({base} + {off})")
                }
            }
        }
    }
}

/// Activation transform applied per element at store time — transcribed
/// per interpreter call site (`f32::max` compiles to `maxss`, which maps
/// `-0.0`/NaN to the second operand; `clamp` keeps them — the emitted
/// comparisons reproduce each exactly).
#[derive(Clone, Copy)]
enum CAct {
    None,
    /// `v.max(0.0)` (fused/standalone f32 relu).
    FMax0,
    /// `v.clamp(0.0, 6.0)` (fused/standalone f32 relu6).
    FClamp06,
    /// i8 `v.max(lo)`.
    I8Lo(i8),
    /// i8 `v.clamp(lo, hi)`.
    I8LoHi(i8, i8),
}

impl CAct {
    /// Fused-activation transform in the `out_q` domain (the i8 dispatch
    /// arm's post-kernel pass).
    fn fused(dtype: DType, act: Act, out_q: QuantParams) -> CAct {
        match (dtype, act) {
            (_, Act::Linear) => CAct::None,
            (DType::F32, Act::Relu) => CAct::FMax0,
            (DType::F32, Act::Relu6) => CAct::FClamp06,
            (_, Act::Relu) => CAct::I8Lo(out_q.zero_point.clamp(-128, 127) as i8),
            (_, Act::Relu6) => {
                let lo = out_q.zero_point.clamp(-128, 127) as i8;
                let hi = out_q.quantize_one(6.0).max(lo);
                CAct::I8LoHi(lo, hi)
            }
        }
    }

    fn apply(&self, cw: &mut Cw, v: &str) {
        match self {
            CAct::None => {}
            CAct::FMax0 => cw.l(format!("if (!({v} > 0.0f)) {v} = 0.0f;")),
            CAct::FClamp06 => {
                cw.l(format!("if ({v} < 0.0f) {v} = 0.0f;"));
                cw.l(format!("else if ({v} > 6.0f) {v} = 6.0f;"));
            }
            CAct::I8Lo(lo) => cw.l(format!("if ({v} < {lo}) {v} = {lo};")),
            CAct::I8LoHi(lo, hi) => {
                cw.l(format!("if ({v} < {lo}) {v} = {lo};"));
                cw.l(format!("else if ({v} > {hi}) {v} = {hi};"));
            }
        }
    }
}

/// Saturate an `int32_t` expression into `[-128, 127]` (Rust `clamp`).
fn clamp_i8(cw: &mut Cw, v: &str) {
    cw.l(format!("if ({v} < -128) {v} = -128;"));
    cw.l(format!("if ({v} > 127) {v} = 127;"));
}

/// Geometry of a (possibly banded) windowed op, fully resolved to
/// compile-time constants.
struct WinGeom {
    ish: Hwc,
    osh: Hwc,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad_y: isize,
    pad_x: isize,
    /// First output channel of the band within the full weight tensor.
    c0: usize,
    /// Total output channels of the full weight tensor (column stride).
    c_total: usize,
}

impl WinGeom {
    /// Whether the `iy` / `ix` bounds guards can ever fire; guards that
    /// provably cannot are not emitted.
    fn guards_y(&self) -> (bool, bool) {
        let max_iy = (self.osh.h as isize - 1) * self.stride.0 as isize
            + self.kernel.0 as isize
            - 1
            - self.pad_y;
        (self.pad_y > 0, max_iy >= self.ish.h as isize)
    }

    fn guards_x(&self) -> (bool, bool) {
        let max_ix = (self.osh.w as isize - 1) * self.stride.1 as isize
            + self.kernel.1 as isize
            - 1
            - self.pad_x;
        (self.pad_x > 0, max_ix >= self.ish.w as isize)
    }
}

/// Emit `int iy = ...;` plus its (needed) guards; returns after the
/// optional `continue`.
#[allow(clippy::too_many_arguments)]
fn emit_tap_guard(cw: &mut Cw, var: &str, base: &str, stride: usize, tap: &str, pad: isize, extent: usize, guards: (bool, bool)) {
    cw.l(format!("int {var} = {};", tap_idx(base, stride, tap, pad)));
    match guards {
        (true, true) => cw.l(format!("if ({var} < 0 || {var} >= {extent}) continue;")),
        (true, false) => cw.l(format!("if ({var} < 0) continue;")),
        (false, true) => cw.l(format!("if ({var} >= {extent}) continue;")),
        (false, false) => {}
    }
}

/// Resolve the geometry of a whole windowed op.
fn whole_geom(cx: &Ctx, op: &Op, kernel: (usize, usize), stride: (usize, usize), padding: Padding, c_total: usize) -> WinGeom {
    let ish = Hwc::from_shape(cx.shape(op.inputs[0]));
    let osh = Hwc::from_shape(cx.shape(op.output));
    let pad_y = pad_amounts(ish.h, kernel.0, stride.0, padding, osh.h) as isize;
    let pad_x = pad_amounts(ish.w, kernel.1, stride.1, padding, osh.w) as isize;
    WinGeom { ish, osh, kernel, stride, pad_y, pad_x, c0: 0, c_total }
}

/// Resolve the geometry of a `Partial`/`PartialInto` band (mirrors
/// `interp::partial_pads` and the channel-band selection in
/// `partial_band_f32`/`_i8`).
#[allow(clippy::too_many_arguments)]
fn band_geom(cx: &Ctx, op: &Op, band: &[usize], axis: SplitAxis, pad: isize, offset: usize, kernel: (usize, usize), stride: (usize, usize), padding: Padding, w_cout_dim: Option<usize>) -> WinGeom {
    let ish = Hwc::from_shape(cx.shape(op.inputs[0]));
    let osh = Hwc::from_shape(band);
    let (pad_y, pad_x) = partial_pads(axis, pad, ish, osh, kernel, stride, padding);
    let (c0, c_total) = match (axis, w_cout_dim) {
        (SplitAxis::Channels, Some(d)) => (offset, cx.shape(op.weights[0])[d]),
        // Depthwise bands along channels read the input slab's channels.
        (SplitAxis::Channels, None) => (offset, cx.shape(op.weights[0])[2]),
        (_, Some(_)) => (0, osh.c),
        (_, None) => (0, ish.c),
    };
    WinGeom { ish, osh, kernel, stride, pad_y, pad_x, c0, c_total }
}

/// Emit one scheduled operator as `static void {sym}_step{N}(void)`.
pub(crate) fn emit_step(cx: &Ctx, step: usize, op: &Op, h: &mut Helpers) -> Result<String> {
    let mut cw = Cw::new();
    let name = op.name.replace("*/", "* /");
    cw.l(format!("/* step {step}: {name} ({}) */", op.kind.name()));
    cw.open(format!("static void {}_step{step}(void) {{", cx.sym));
    if cx.dtype == DType::U8 {
        emit_synthetic(cx, &mut cw, op)?;
    } else {
        emit_op(cx, &mut cw, op, h)?;
    }
    cw.close();
    Ok(cw.finish())
}

/// The u8 path: every op kind executes the interpreter's deterministic
/// byte-mixing (`ops::synthetic_bytes`) over all of its inputs.
fn emit_synthetic(cx: &Ctx, cw: &mut Cw, op: &Op) -> Result<()> {
    let n = cx.elems(op.output);
    cw.l(format!("uint8_t *o = {};", cx.t(op.output)));
    cw.open(format!("for (int i = 0; i < {n}; i++) {{"));
    cw.l("unsigned int acc = (0x9Eu + (unsigned int)i) & 0xFFu;");
    for &t in &op.inputs {
        let len = cx.elems(t);
        if len == 0 {
            continue;
        }
        cw.l(format!("acc = (acc * 31u + (unsigned int){}[i % {len}]) & 0xFFu;", cx.t(t)));
    }
    cw.l("o[i] = (uint8_t)acc;");
    cw.close();
    Ok(())
}

/// The f32/i8 dispatch — one arm per interpreter-supported op kind.
fn emit_op(cx: &Ctx, cw: &mut Cw, op: &Op, h: &mut Helpers) -> Result<()> {
    let unsup = |what: &str| anyhow!("codegen: unsupported op `{}` ({what})", op.name);
    match &op.kind {
        OpKind::Conv2D { kernel, stride, padding, act } => {
            let geom = whole_geom(cx, op, *kernel, *stride, *padding, Hwc::from_shape(cx.shape(op.output)).c);
            emit_conv(cx, cw, h, op, &geom, *act, &Dst::whole(cx, op.output))
        }
        OpKind::DepthwiseConv2D { kernel, stride, padding, act } => {
            let geom = whole_geom(cx, op, *kernel, *stride, *padding, Hwc::from_shape(cx.shape(op.inputs[0])).c);
            emit_dwconv(cx, cw, h, op, &geom, *act, &Dst::whole(cx, op.output))
        }
        OpKind::Dense { act } => {
            emit_dense(cx, cw, h, op, 0, cx.elems(op.output), *act, &Dst::whole(cx, op.output))
        }
        OpKind::Add => emit_add(cx, cw, h, op),
        OpKind::Concat => emit_concat(cx, cw, h, op),
        OpKind::Relu | OpKind::Relu6 => {
            let band = cx.shape(op.output).to_vec();
            emit_reluish(cx, cw, op, &op.kind, &band, &Dst::whole(cx, op.output))
        }
        OpKind::MaxPool2D { kernel, stride, padding } => {
            let geom = whole_geom(cx, op, *kernel, *stride, *padding, 0);
            emit_pool(cx, cw, h, op, &geom, false, &Dst::whole(cx, op.output))
        }
        OpKind::AvgPool2D { kernel, stride, padding } => {
            if cx.dtype == DType::I8 {
                return Err(unsup("i8 avgpool (unused in zoo)"));
            }
            let geom = whole_geom(cx, op, *kernel, *stride, *padding, 0);
            emit_pool(cx, cw, h, op, &geom, true, &Dst::whole(cx, op.output))
        }
        OpKind::GlobalAvgPool => emit_gap(cx, cw, h, op),
        OpKind::Softmax => emit_softmax(cx, cw, h, op),
        OpKind::BatchNorm { eps } => {
            if cx.dtype == DType::I8 {
                return Err(unsup("i8 batchnorm (fold it first)"));
            }
            let band = cx.shape(op.output).to_vec();
            emit_batchnorm(cx, cw, h, op, *eps, 0, &band, &Dst::whole(cx, op.output))
        }
        OpKind::Reshape => {
            cw.l(format!(
                "memcpy({}, {}, {}u);",
                cx.t(op.output),
                cx.t(op.inputs[0]),
                cx.elems(op.output) * cx.dtype.size()
            ));
            Ok(())
        }
        OpKind::Synthetic { .. } => Err(unsup("synthetic op with a typed dtype")),
        OpKind::Partial { inner, axis, pad, offset } => {
            let band = cx.shape(op.output).to_vec();
            emit_partial(cx, cw, h, op, inner, *axis, *pad, *offset, &band, Dst::whole(cx, op.output))
        }
        OpKind::PartialInto { inner, axis, pad, offset, len } => {
            // The output shares the accumulator's buffer (asserted at
            // plan time), so the interpreter's carry copy is a no-op here
            // and only the band is written, in place.
            let band = band_shape_of(cx.shape(op.output), *axis, *len);
            emit_partial(cx, cw, h, op, inner, *axis, *pad, *offset, &band, Dst::band(cx, op.output, *axis, *offset))
        }
        OpKind::ConcatSlices { axis } => emit_concat_slices(cx, cw, op, *axis),
    }
}

/// Band dispatch shared by `Partial` and `PartialInto` — mirrors
/// `Interpreter::partial_band_f32` / `partial_band_i8`.
#[allow(clippy::too_many_arguments)]
fn emit_partial(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op, inner: &OpKind, axis: SplitAxis, pad: isize, offset: usize, band: &[usize], dst: Dst) -> Result<()> {
    match inner {
        OpKind::Conv2D { kernel, stride, padding, act } => {
            let geom = band_geom(cx, op, band, axis, pad, offset, *kernel, *stride, *padding, Some(3));
            emit_conv(cx, cw, h, op, &geom, *act, &dst)
        }
        OpKind::DepthwiseConv2D { kernel, stride, padding, act } => {
            let geom = band_geom(cx, op, band, axis, pad, offset, *kernel, *stride, *padding, None);
            emit_dwconv(cx, cw, h, op, &geom, *act, &dst)
        }
        OpKind::MaxPool2D { kernel, stride, padding } => {
            let geom = band_geom(cx, op, band, axis, pad, offset, *kernel, *stride, *padding, None);
            emit_pool(cx, cw, h, op, &geom, false, &dst)
        }
        OpKind::AvgPool2D { kernel, stride, padding } => {
            if cx.dtype == DType::I8 {
                bail!("codegen: unsupported op `{}` (partial AvgPool2D (i8))", op.name);
            }
            let geom = band_geom(cx, op, band, axis, pad, offset, *kernel, *stride, *padding, None);
            emit_pool(cx, cw, h, op, &geom, true, &dst)
        }
        OpKind::Dense { act } => {
            emit_dense(cx, cw, h, op, offset, band.iter().product(), *act, &dst)
        }
        OpKind::Relu | OpKind::Relu6 => emit_reluish(cx, cw, op, inner, band, &dst),
        OpKind::BatchNorm { eps } => {
            if cx.dtype == DType::I8 {
                bail!("codegen: unsupported op `{}` (partial BatchNorm (i8))", op.name);
            }
            let c0 = if axis == SplitAxis::Channels { offset } else { 0 };
            emit_batchnorm(cx, cw, h, op, *eps, c0, band, &dst)
        }
        other => bail!("codegen: unsupported op `{}` (partial {})", op.name, other.name()),
    }
}

/// Open the per-element loops of a pointwise band write; returns
/// `(src_index, dst_lvalue, channel_expr, n_loops)`.
fn open_band(cw: &mut Cw, band: &[usize], dst: &Dst) -> (String, String, String, usize) {
    let n: usize = band.iter().product();
    if dst.is_flat_cover(band) {
        let bc = *band.last().unwrap_or(&1);
        cw.open(format!("for (int i = 0; i < {n}; i++) {{"));
        let dstl = format!("{}[i]", dst.flat_ptr());
        return ("i".into(), dstl, format!("i % {bc}"), 1);
    }
    let b = Hwc::from_shape(band);
    let (base, w, c, ry, rx, rc) = match dst {
        Dst::Hwc { base, w, c, ry, rx, rc } => (base.clone(), *w, *c, *ry, *rx, *rc),
        Dst::Flat { .. } => unreachable!("flat dst is always a flat cover"),
    };
    cw.open(format!("for (int y = 0; y < {}; y++) {{", b.h));
    cw.open(format!("for (int x_ = 0; x_ < {}; x_++) {{", b.w));
    cw.open(format!("for (int ch = 0; ch < {}; ch++) {{", b.c));
    let src = format!("(y * {} + x_) * {} + ch", b.w, b.c);
    let ye = shifted("y", ry);
    let xe = shifted("x_", rx);
    let ce = shifted("ch", rc);
    let dstl = format!("{base}[(({ye}) * {w} + ({xe})) * {c} + {ce}]");
    (src, dstl, "ch".into(), 3)
}

fn close_band(cw: &mut Cw, n: usize) {
    for _ in 0..n {
        cw.close();
    }
}

/// Standalone `Relu`/`Relu6` (whole op or band) — the kernels apply the
/// transform in the *input* quantization domain for i8.
fn emit_reluish(cx: &Ctx, cw: &mut Cw, op: &Op, kind: &OpKind, band: &[usize], dst: &Dst) -> Result<()> {
    let in_q = cx.qp(op.inputs[0]);
    let lo = in_q.zero_point.clamp(-128, 127) as i8;
    let act = match (cx.dtype, kind) {
        (DType::F32, OpKind::Relu) => CAct::FMax0,
        (DType::F32, OpKind::Relu6) => CAct::FClamp06,
        (DType::I8, OpKind::Relu) => CAct::I8Lo(lo),
        (DType::I8, OpKind::Relu6) => CAct::I8LoHi(lo, in_q.quantize_one(6.0).max(lo)),
        _ => bail!("codegen: unsupported op `{}` (relu dtype)", op.name),
    };
    let ety = cx.ety();
    cw.l(format!("const {ety} *x = {};", cx.t(op.inputs[0])));
    let (src, dstl, _, n) = open_band(cw, band, dst);
    cw.l(format!("{ety} v = x[{src}];"));
    act.apply(cw, "v");
    cw.l(format!("{dstl} = v;"));
    close_band(cw, n);
    Ok(())
}

/// f32 `BatchNorm` (whole op or band): per-channel affine with the
/// channel band offset folded in.
#[allow(clippy::too_many_arguments)]
fn emit_batchnorm(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op, eps: f32, c0: usize, band: &[usize], dst: &Dst) -> Result<()> {
    h.math = true;
    let (gamma, beta, mean, var) =
        (cx.w(op.weights[0]), cx.w(op.weights[1]), cx.w(op.weights[2]), cx.w(op.weights[3]));
    let eps = c_f32(eps)?;
    cw.l(format!("const float *x = {};", cx.t(op.inputs[0])));
    let (src, dstl, chexpr, n) = open_band(cw, band, dst);
    let ch = if c0 == 0 { format!("({chexpr})") } else { format!("({c0} + ({chexpr}))") };
    cw.l(format!("int ch_ = {ch};"));
    cw.l(format!(
        "{dstl} = {gamma}[ch_] * (x[{src}] - {mean}[ch_]) / sqrtf({var}[ch_] + {eps}) + {beta}[ch_];"
    ));
    close_band(cw, n);
    Ok(())
}

/// Conv2D (whole or band), f32 and i8 — transcribes
/// `ops::conv2d_with_pads` / `quant::conv2d_i8_with_pads`.
fn emit_conv(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op, g: &WinGeom, act: Act, dst: &Dst) -> Result<()> {
    let (kh, kw) = g.kernel;
    let (sh, sw) = g.stride;
    let cout = g.osh.c;
    let cin = g.ish.c;
    let (w, b) = (cx.w(op.weights[0]), cx.w(op.weights[1]));
    let is_i8 = cx.dtype == DType::I8;
    let (ety, aty) = if is_i8 { ("int8_t", "int32_t") } else { ("float", "float") };
    let out_q = cx.qp(op.output);
    let in_q = cx.qp(op.inputs[0]);
    let fused = CAct::fused(cx.dtype, act, out_q);
    cw.l(format!("const {ety} *x = {};", cx.t(op.inputs[0])));
    cw.open(format!("for (int oy = 0; oy < {}; oy++) {{", g.osh.h));
    cw.open(format!("for (int ox = 0; ox < {}; ox++) {{", g.osh.w));
    cw.l(format!("{aty} acc[{cout}];"));
    cw.l(format!("for (int oc = 0; oc < {cout}; oc++) acc[oc] = {b}[{}];", shifted("oc", g.c0)));
    cw.open(format!("for (int ky = 0; ky < {kh}; ky++) {{"));
    emit_tap_guard(cw, "iy", "oy", sh, "ky", g.pad_y, g.ish.h, g.guards_y());
    cw.open(format!("for (int kx = 0; kx < {kw}; kx++) {{"));
    emit_tap_guard(cw, "ix", "ox", sw, "kx", g.pad_x, g.ish.w, g.guards_x());
    cw.l(format!("const {ety} *px = x + (iy * {} + ix) * {cin};", g.ish.w));
    let wbase = format!("(ky * {kw} + kx) * {cin} * {}", g.c_total);
    let wbase = if g.c0 == 0 { wbase } else { format!("{wbase} + {}", g.c0) };
    cw.l(format!("const {ety} *pw = {w} + {wbase};"));
    cw.open(format!("for (int ic = 0; ic < {cin}; ic++) {{"));
    if is_i8 {
        let zp = in_q.zero_point;
        let iv = if zp == 0 {
            "int32_t iv = (int32_t)px[ic];".to_string()
        } else {
            format!("int32_t iv = (int32_t)px[ic] - {zp};")
        };
        cw.l(iv);
        cw.l("if (iv == 0) continue;");
        cw.l(format!("const int8_t *wc = pw + ic * {};", g.c_total));
        cw.l(format!("for (int oc = 0; oc < {cout}; oc++) acc[oc] += iv * (int32_t)wc[oc];"));
    } else {
        cw.l("float iv = px[ic];");
        cw.l(format!("const float *wc = pw + ic * {};", g.c_total));
        cw.l(format!("for (int oc = 0; oc < {cout}; oc++) acc[oc] += iv * wc[oc];"));
    }
    cw.close(); // ic
    cw.close(); // kx
    cw.close(); // ky
    cw.l(format!("{ety} *po = {};", dst.row_ptr("oy", "ox")?));
    cw.open(format!("for (int oc = 0; oc < {cout}; oc++) {{"));
    if is_i8 {
        h.requant = true;
        let w_scale = cx.qp(op.weights[0]).scale;
        let fm = fixed_mult(in_q.scale, w_scale, out_q.scale)?;
        cw.l(format!(
            "int8_t q = {}_requant(acc[oc], {}, {}, {});",
            cx.sym, fm.m, fm.sh, out_q.zero_point
        ));
        fused.apply(cw, "q");
        cw.l("po[oc] = q;");
    } else {
        cw.l("float v = acc[oc];");
        fused.apply(cw, "v");
        cw.l("po[oc] = v;");
    }
    cw.close(); // oc store
    cw.close(); // ox
    cw.close(); // oy
    Ok(())
}

/// The conv/dense/dwconv requantization multiplier — identical
/// construction to the interpreter's (`FixedMult::new(si*sw/so)`).
fn fixed_mult(in_scale: f32, w_scale: f32, out_scale: f32) -> Result<FixedMult> {
    let mult = (in_scale as f64) * (w_scale as f64) / (out_scale as f64);
    if !(mult > 0.0 && mult.is_finite()) {
        bail!("non-positive requantization multiplier {mult}");
    }
    Ok(FixedMult::new(mult))
}

/// DepthwiseConv2D (whole or band) — transcribes
/// `ops::dwconv2d_with_pads` / `quant::dwconv2d_i8_with_pads` (note: the
/// i8 depthwise kernel has no zero-skip, unlike i8 conv).
fn emit_dwconv(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op, g: &WinGeom, act: Act, dst: &Dst) -> Result<()> {
    let (kh, kw) = g.kernel;
    let (sh, sw) = g.stride;
    let cb = g.ish.c; // band channels: the slab carries only the band
    let (w, b) = (cx.w(op.weights[0]), cx.w(op.weights[1]));
    let is_i8 = cx.dtype == DType::I8;
    let (ety, aty) = if is_i8 { ("int8_t", "int32_t") } else { ("float", "float") };
    let out_q = cx.qp(op.output);
    let in_q = cx.qp(op.inputs[0]);
    let fused = CAct::fused(cx.dtype, act, out_q);
    cw.l(format!("const {ety} *x = {};", cx.t(op.inputs[0])));
    cw.open(format!("for (int oy = 0; oy < {}; oy++) {{", g.osh.h));
    cw.open(format!("for (int ox = 0; ox < {}; ox++) {{", g.osh.w));
    cw.l(format!("{aty} acc[{cb}];"));
    cw.l(format!("for (int j = 0; j < {cb}; j++) acc[j] = {b}[{}];", shifted("j", g.c0)));
    cw.open(format!("for (int ky = 0; ky < {kh}; ky++) {{"));
    emit_tap_guard(cw, "iy", "oy", sh, "ky", g.pad_y, g.ish.h, g.guards_y());
    cw.open(format!("for (int kx = 0; kx < {kw}; kx++) {{"));
    emit_tap_guard(cw, "ix", "ox", sw, "kx", g.pad_x, g.ish.w, g.guards_x());
    cw.l(format!("const {ety} *pi = x + (iy * {} + ix) * {cb};", g.ish.w));
    let wrow = format!("(ky * {kw} + kx) * {}", g.c_total);
    let wrow = if g.c0 == 0 { wrow } else { format!("{wrow} + {}", g.c0) };
    cw.l(format!("const {ety} *pw = {w} + {wrow};"));
    if is_i8 {
        let zp = in_q.zero_point;
        let iv = if zp == 0 { "(int32_t)pi[j]".to_string() } else { format!("((int32_t)pi[j] - {zp})") };
        cw.l(format!("for (int j = 0; j < {cb}; j++) acc[j] += {iv} * (int32_t)pw[j];"));
    } else {
        cw.l(format!("for (int j = 0; j < {cb}; j++) acc[j] += pi[j] * pw[j];"));
    }
    cw.close(); // kx
    cw.close(); // ky
    cw.l(format!("{ety} *po = {};", dst.row_ptr("oy", "ox")?));
    cw.open(format!("for (int j = 0; j < {cb}; j++) {{"));
    if is_i8 {
        h.requant = true;
        let fm = fixed_mult(in_q.scale, cx.qp(op.weights[0]).scale, out_q.scale)?;
        cw.l(format!(
            "int8_t q = {}_requant(acc[j], {}, {}, {});",
            cx.sym, fm.m, fm.sh, out_q.zero_point
        ));
        fused.apply(cw, "q");
        cw.l("po[j] = q;");
    } else {
        cw.l("float v = acc[j];");
        fused.apply(cw, "v");
        cw.l("po[j] = v;");
    }
    cw.close();
    cw.close(); // ox
    cw.close(); // oy
    Ok(())
}

/// Dense (whole or column band) — transcribes `ops::dense_cols`
/// (output-major) and `quant::dense_cols_i8` (input-major, zero-skip).
#[allow(clippy::too_many_arguments)]
fn emit_dense(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op, col0: usize, n_out: usize, act: Act, dst: &Dst) -> Result<()> {
    let n_in = cx.elems(op.inputs[0]);
    let n_cols = cx.shape(op.weights[0])[1];
    let (w, b) = (cx.w(op.weights[0]), cx.w(op.weights[1]));
    let out_q = cx.qp(op.output);
    let in_q = cx.qp(op.inputs[0]);
    let fused = CAct::fused(cx.dtype, act, out_q);
    let o = dst.flat_ptr();
    if cx.dtype == DType::I8 {
        h.requant = true;
        let fm = fixed_mult(in_q.scale, cx.qp(op.weights[0]).scale, out_q.scale)?;
        cw.l(format!("const int8_t *x = {};", cx.t(op.inputs[0])));
        cw.l(format!("int8_t *o = {o};"));
        cw.l(format!("int32_t acc[{n_out}];"));
        cw.l(format!("for (int oi = 0; oi < {n_out}; oi++) acc[oi] = {b}[{}];", shifted("oi", col0)));
        cw.open(format!("for (int i = 0; i < {n_in}; i++) {{"));
        let zp = in_q.zero_point;
        if zp == 0 {
            cw.l("int32_t iv = (int32_t)x[i];");
        } else {
            cw.l(format!("int32_t iv = (int32_t)x[i] - {zp};"));
        }
        cw.l("if (iv == 0) continue;");
        let wrow = if col0 == 0 { format!("{w} + i * {n_cols}") } else { format!("{w} + i * {n_cols} + {col0}") };
        cw.l(format!("const int8_t *pw = {wrow};"));
        cw.l(format!("for (int oi = 0; oi < {n_out}; oi++) acc[oi] += iv * (int32_t)pw[oi];"));
        cw.close();
        cw.open(format!("for (int oi = 0; oi < {n_out}; oi++) {{"));
        cw.l(format!(
            "int8_t q = {}_requant(acc[oi], {}, {}, {});",
            cx.sym, fm.m, fm.sh, out_q.zero_point
        ));
        fused.apply(cw, "q");
        cw.l("o[oi] = q;");
        cw.close();
    } else {
        cw.l(format!("const float *x = {};", cx.t(op.inputs[0])));
        cw.l(format!("float *o = {o};"));
        cw.open(format!("for (int oi = 0; oi < {n_out}; oi++) {{"));
        cw.l(format!("float a = {b}[{}];", shifted("oi", col0)));
        let wi = if col0 == 0 { format!("i * {n_cols} + oi") } else { format!("i * {n_cols} + {col0} + oi") };
        cw.l(format!("for (int i = 0; i < {n_in}; i++) a += x[i] * {w}[{wi}];"));
        fused.apply(cw, "a");
        cw.l("o[oi] = a;");
        cw.close();
    }
    Ok(())
}

/// Elementwise Add — f32 direct, i8 via the dequant/requant round trip of
/// `quant::add_i8` (f64 intermediates, scale ratios folded at gen time).
fn emit_add(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op) -> Result<()> {
    if op.inputs.len() != 2 {
        bail!("codegen: Add `{}` with {} inputs", op.name, op.inputs.len());
    }
    let n = cx.elems(op.output);
    let (a, b, o) = (cx.t(op.inputs[0]), cx.t(op.inputs[1]), cx.t(op.output));
    if cx.dtype == DType::F32 {
        cw.l(format!("const float *a = {a};"));
        cw.l(format!("const float *b = {b};"));
        cw.l(format!("float *o = {o};"));
        cw.l(format!("for (int i = 0; i < {n}; i++) o[i] = a[i] + b[i];"));
        return Ok(());
    }
    h.sat_i32_d = true;
    h.math = true;
    let (aq, bq, oq) = (cx.qp(op.inputs[0]), cx.qp(op.inputs[1]), cx.qp(op.output));
    // The interpreter divides the f32 scales first, then widens.
    let ma = c_f64((aq.scale / oq.scale) as f64)?;
    let mb = c_f64((bq.scale / oq.scale) as f64)?;
    cw.l(format!("const int8_t *a = {a};"));
    cw.l(format!("const int8_t *b = {b};"));
    cw.l(format!("int8_t *o = {o};"));
    cw.open(format!("for (int i = 0; i < {n}; i++) {{"));
    cw.l(format!("double av = (double)((int32_t)a[i] - {}) * {ma};", aq.zero_point));
    cw.l(format!("double bv = (double)((int32_t)b[i] - {}) * {mb};", bq.zero_point));
    cw.l(format!("int32_t v = {}_sat_i32_d(round(av + bv)) + {};", cx.sym, oq.zero_point));
    clamp_i8(cw, "v");
    cw.l("o[i] = (int8_t)v;");
    cw.close();
    Ok(())
}

/// Channel-axis Concat — f32 row copies; i8 requantizes every element
/// into the output domain (the interpreter's per-element round trip).
fn emit_concat(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op) -> Result<()> {
    let osh = Hwc::from_shape(cx.shape(op.output));
    let ety = cx.ety();
    cw.l(format!("{ety} *o = {};", cx.t(op.output)));
    let mut c_off = 0usize;
    for (pi, &t) in op.inputs.iter().enumerate() {
        let ish = Hwc::from_shape(cx.shape(t));
        cw.l(format!("/* part {pi}: c {} at offset {c_off} */", ish.c));
        cw.open("{");
        cw.l(format!("const {ety} *p = {};", cx.t(t)));
        if cx.dtype == DType::F32 {
            cw.open(format!("for (int y = 0; y < {}; y++) {{", ish.h));
            cw.open(format!("for (int x_ = 0; x_ < {}; x_++) {{", ish.w));
            cw.l(format!(
                "memcpy(o + (y * {} + x_) * {} + {c_off}, p + (y * {} + x_) * {}, {}u);",
                osh.w,
                osh.c,
                ish.w,
                ish.c,
                ish.c * 4
            ));
            cw.close();
            cw.close();
        } else {
            h.sat_i32_f = true;
            h.math = true;
            let iq = cx.qp(t);
            let oq = cx.qp(op.output);
            let si = c_f32(iq.scale)?;
            let so = c_f32(oq.scale)?;
            cw.open(format!("for (int y = 0; y < {}; y++) {{", ish.h));
            cw.open(format!("for (int x_ = 0; x_ < {}; x_++) {{", ish.w));
            cw.open(format!("for (int ch = 0; ch < {}; ch++) {{", ish.c));
            cw.l(format!(
                "float v = (float)((int32_t)p[(y * {} + x_) * {} + ch] - {}) * {si};",
                ish.w, ish.c, iq.zero_point
            ));
            cw.l(format!(
                "int32_t q = {}_sat_i32_f(roundf(v / {so})) + {};",
                cx.sym, oq.zero_point
            ));
            clamp_i8(cw, "q");
            cw.l(format!(
                "o[(y * {} + x_) * {} + {c_off} + ch] = (int8_t)q;",
                osh.w, osh.c
            ));
            cw.close();
            cw.close();
            cw.close();
        }
        cw.close();
        c_off += ish.c;
    }
    Ok(())
}

/// ConcatSlices: the split join. A pure same-quantization copy in every
/// dtype — transcribes `ops::concat_slices`' three placement modes.
fn emit_concat_slices(cx: &Ctx, cw: &mut Cw, op: &Op, axis: SplitAxis) -> Result<()> {
    let out_shape = cx.shape(op.output).to_vec();
    let esz = cx.dtype.size();
    let ety = cx.ety();
    cw.l(format!("{ety} *o = {};", cx.t(op.output)));
    if out_shape.len() != 4 || axis == SplitAxis::Rows {
        let mut off = 0usize;
        for &t in &op.inputs {
            let n = cx.elems(t);
            let dst = if off == 0 { "o".to_string() } else { format!("o + {off}") };
            cw.l(format!("memcpy({dst}, {}, {}u);", cx.t(t), n * esz));
            off += n;
        }
        return Ok(());
    }
    let osh = Hwc::from_shape(&out_shape);
    match axis {
        SplitAxis::Cols => {
            let mut x_off = 0usize;
            for &t in &op.inputs {
                let ish = Hwc::from_shape(cx.shape(t));
                cw.open(format!("for (int y = 0; y < {}; y++) {{", ish.h));
                cw.l(format!(
                    "memcpy(o + (y * {} + {x_off}) * {}, {} + y * {}, {}u);",
                    osh.w,
                    osh.c,
                    cx.t(t),
                    ish.w * ish.c,
                    ish.w * ish.c * esz
                ));
                cw.close();
                x_off += ish.w;
            }
        }
        SplitAxis::Channels => {
            let mut c_off = 0usize;
            for &t in &op.inputs {
                let ish = Hwc::from_shape(cx.shape(t));
                cw.open(format!("for (int y = 0; y < {}; y++) {{", ish.h));
                cw.open(format!("for (int x_ = 0; x_ < {}; x_++) {{", ish.w));
                cw.l(format!(
                    "memcpy(o + (y * {} + x_) * {} + {c_off}, {} + (y * {} + x_) * {}, {}u);",
                    osh.w,
                    osh.c,
                    cx.t(t),
                    ish.w,
                    ish.c,
                    ish.c * esz
                ));
                cw.close();
                cw.close();
                c_off += ish.c;
            }
        }
        SplitAxis::Rows => unreachable!("handled by the flat path"),
    }
    Ok(())
}

/// Max/Avg 2D pooling (whole or band). The i8 path is max-only (the
/// interpreter rejects i8 avgpool); `-128` seeds the max exactly like
/// `i8::MIN`, `-INFINITY` like `f32::NEG_INFINITY`, and the f32 max
/// chain reproduces `maxss` tie behavior via `!(m > t)`.
fn emit_pool(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op, g: &WinGeom, avg: bool, dst: &Dst) -> Result<()> {
    let (kh, kw) = g.kernel;
    let (sh, sw) = g.stride;
    let c = g.osh.c;
    let ety = cx.ety();
    let (gy, gx) = (g.guards_y(), g.guards_x());
    let guarded = gy.0 || gy.1 || gx.0 || gx.1;
    cw.l(format!("const {ety} *x = {};", cx.t(op.inputs[0])));
    cw.open(format!("for (int oy = 0; oy < {}; oy++) {{", g.osh.h));
    cw.open(format!("for (int ox = 0; ox < {}; ox++) {{", g.osh.w));
    cw.l(format!("{ety} *po = {};", dst.row_ptr("oy", "ox")?));
    cw.open(format!("for (int ch = 0; ch < {c}; ch++) {{"));
    if avg {
        cw.l("float accv = 0.0f;");
        if guarded {
            cw.l("int taps = 0;");
        }
    } else if cx.dtype == DType::F32 {
        h.math = true;
        cw.l("float mv = -INFINITY;");
    } else {
        cw.l("int8_t mv = -128;");
    }
    cw.open(format!("for (int ky = 0; ky < {kh}; ky++) {{"));
    emit_tap_guard(cw, "iy", "oy", sh, "ky", g.pad_y, g.ish.h, gy);
    cw.open(format!("for (int kx = 0; kx < {kw}; kx++) {{"));
    emit_tap_guard(cw, "ix", "ox", sw, "kx", g.pad_x, g.ish.w, gx);
    let tap = format!("x[(iy * {} + ix) * {} + ch]", g.ish.w, g.ish.c);
    if avg {
        cw.l(format!("accv += {tap};"));
        if guarded {
            cw.l("taps++;");
        }
    } else if cx.dtype == DType::F32 {
        cw.l(format!("float tv = {tap};"));
        cw.l("if (!(mv > tv)) mv = tv;");
    } else {
        cw.l(format!("int8_t tv = {tap};"));
        cw.l("if (tv > mv) mv = tv;");
    }
    cw.close(); // kx
    cw.close(); // ky
    if avg {
        if guarded {
            cw.l("int d = taps;");
            cw.l("if (d < 1) d = 1;");
            cw.l("po[ch] = accv / (float)d;");
        } else {
            // Every tap is provably in bounds, so the divisor is a
            // compile-time constant (same value the dynamic count hits).
            cw.l(format!("po[ch] = accv / {};", c_f32((kh * kw) as f32)?));
        }
    } else {
        cw.l("po[ch] = mv;");
    }
    cw.close(); // ch
    cw.close(); // ox
    cw.close(); // oy
    Ok(())
}

/// GlobalAvgPool — channel-major accumulation exactly like the kernels
/// (`f32` sums f32; `i8` sums zero-point-shifted i64 then rounds in f64).
fn emit_gap(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op) -> Result<()> {
    let ish = Hwc::from_shape(cx.shape(op.inputs[0]));
    let (hh, ww, c) = (ish.h, ish.w, ish.c);
    let ety = cx.ety();
    cw.l(format!("const {ety} *x = {};", cx.t(op.inputs[0])));
    cw.l(format!("{ety} *o = {};", cx.t(op.output)));
    cw.open(format!("for (int ch = 0; ch < {c}; ch++) {{"));
    if cx.dtype == DType::F32 {
        cw.l("float accv = 0.0f;");
        cw.open(format!("for (int y = 0; y < {hh}; y++) {{"));
        cw.l(format!("for (int x_ = 0; x_ < {ww}; x_++) accv += x[(y * {ww} + x_) * {c} + ch];"));
        cw.close();
        cw.l(format!("o[ch] = accv / {};", c_f32((hh * ww) as f32)?));
    } else {
        h.sat_i32_d = true;
        h.math = true;
        let q = cx.qp(op.inputs[0]);
        cw.l("int64_t accv = 0;");
        cw.open(format!("for (int y = 0; y < {hh}; y++) {{"));
        let shift = if q.zero_point == 0 { String::new() } else { format!(" - {}", q.zero_point) };
        cw.l(format!(
            "for (int x_ = 0; x_ < {ww}; x_++) accv += (int64_t)x[(y * {ww} + x_) * {c} + ch]{shift};"
        ));
        cw.close();
        cw.l(format!(
            "int32_t mean = {}_sat_i32_d(round((double)accv / {})) + {};",
            cx.sym,
            c_f64((hh * ww) as f64)?,
            q.zero_point
        ));
        clamp_i8(cw, "mean");
        cw.l("o[ch] = (int8_t)mean;");
    }
    cw.close();
    Ok(())
}

/// Softmax over the flattened tensor — f32 direct; i8 dequantizes, runs
/// the f32 softmax, then quantizes into the fixed 1/256-scale domain.
fn emit_softmax(cx: &Ctx, cw: &mut Cw, h: &mut Helpers, op: &Op) -> Result<()> {
    h.math = true;
    let n = cx.elems(op.output);
    if n > (1 << 14) {
        bail!("codegen: softmax over {n} elements (stack slab too large)");
    }
    let (x, o) = (cx.t(op.inputs[0]), cx.t(op.output));
    if cx.dtype == DType::F32 {
        cw.l(format!("const float *x = {x};"));
        cw.l(format!("float *o = {o};"));
        cw.l("float mv = -INFINITY;");
        cw.l(format!("for (int i = 0; i < {n}; i++) if (!(mv > x[i])) mv = x[i];"));
        cw.l("float sum = 0.0f;");
        cw.open(format!("for (int i = 0; i < {n}; i++) {{"));
        cw.l("o[i] = expf(x[i] - mv);");
        cw.l("sum += o[i];");
        cw.close();
        cw.l(format!("for (int i = 0; i < {n}; i++) o[i] /= sum;"));
        return Ok(());
    }
    h.sat_i32_f = true;
    let q = cx.qp(op.inputs[0]);
    let si = c_f32(q.scale)?;
    cw.l(format!("const int8_t *x = {x};"));
    cw.l(format!("int8_t *o = {o};"));
    cw.l(format!("float xs[{n}];"));
    cw.l(format!("float ex[{n}];"));
    cw.l("float mv = -INFINITY;");
    cw.l("float sum = 0.0f;");
    cw.open(format!("for (int i = 0; i < {n}; i++) {{"));
    cw.l(format!("xs[i] = (float)((int32_t)x[i] - {}) * {si};", q.zero_point));
    cw.l("if (!(mv > xs[i])) mv = xs[i];");
    cw.close();
    cw.open(format!("for (int i = 0; i < {n}; i++) {{"));
    cw.l("ex[i] = expf(xs[i] - mv);");
    cw.l("sum += ex[i];");
    cw.close();
    cw.open(format!("for (int i = 0; i < {n}; i++) {{"));
    cw.l(format!("int32_t q = {}_sat_i32_f(roundf((ex[i] / sum) * 256.0f)) - 128;", cx.sym));
    clamp_i8(cw, "q");
    cw.l("o[i] = (int8_t)q;");
    cw.close();
    Ok(())
}

//! The serving layer.
//!
//! Two coordinators live here:
//!
//! - [`inference`] — the original inference micro-batcher: request queue,
//!   micro-batching worker pool, and a CSV-over-TCP front-end driving an
//!   [`Engine`] (PJRT runtime or the in-crate micro-interpreter).
//! - [`service`] — the plan-serving coordinator, the fleet-facing product:
//!   accept model uploads (zoo name or `.tflite` bytes), plan
//!   reorder+split+elide under a per-device SRAM budget from
//!   [`crate::mcu::boards`], cache plans in an LRU ([`cache`]) keyed by
//!   `(model content-hash, budget, options fingerprint)`, shed load when
//!   the bounded queue fills, and serve plan/summary JSON over TCP
//!   ([`serve_plans_tcp`]). All planning goes through [`crate::api`], so a
//!   cached plan is bit-identical to a fresh CLI run.

pub mod cache;
pub mod inference;
pub mod service;

pub use cache::{PlanCache, PlanCacheStats, PlanKey};
pub use inference::{
    interp_engine_factory, pjrt_engine_factory, serve_tcp, Coordinator, Engine, EngineFactory,
    MetricsSnapshot, ServeConfig,
};
pub use service::{
    serve_plans_tcp, CachedPlan, ModelRef, PlanError, PlanRequest, PlanServeConfig, PlanService,
    PlanServiceStats, Submission,
};

//! Serving coordinator: request queue, batcher, worker pool, metrics.
//!
//! The paper's system is single-image MCU inference; this layer is the
//! deployment harness around it — the piece a fleet operator runs on the
//! gateway: accept inference requests, group them into micro-batches to
//! amortize dispatch, execute them on a pool of workers (each owning its
//! own PJRT runtime, since the FFI handles are thread-local), and report
//! latency percentiles and throughput.
//!
//! Workers are engine-agnostic via the [`Engine`] trait:
//! - [`pjrt_engine_factory`] — the production path: each worker compiles
//!   the AOT HLO artifact on its own CPU PJRT client.
//! - [`interp_engine_factory`] — the MCU-faithful path: the in-crate
//!   micro-interpreter with arena + defragmentation (also what tests use,
//!   since it needs no artifacts).
//!
//! A minimal TCP front-end ([`serve_tcp`]) speaks a newline-delimited CSV
//! protocol for the end-to-end example.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::error::{anyhow, Result};

use crate::util::stats::LatencyHist;

/// A model-execution backend owned by one worker thread.
pub trait Engine {
    /// Run one inference: input tensor (flattened f32) → output tensor.
    fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>, String>;
}

/// Factory that builds an engine *inside* the worker thread (PJRT handles
/// are not `Send`, so construction must happen on the owning thread).
pub type EngineFactory = Arc<dyn Fn(usize) -> Result<Box<dyn Engine>, String> + Send + Sync>;

/// Engine factory for the PJRT artifact path.
pub fn pjrt_engine_factory(model: String, artifacts_dir: PathBuf) -> EngineFactory {
    Arc::new(move |_worker| {
        struct PjrtEngine {
            rt: crate::runtime::Runtime,
            model: String,
        }
        impl Engine for PjrtEngine {
            fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>, String> {
                let outs = self
                    .rt
                    .execute_f32(&self.model, &[input.to_vec()])
                    .map_err(|e| e.to_string())?;
                Ok(outs.into_iter().next().unwrap_or_default())
            }
        }
        let mut rt = crate::runtime::Runtime::cpu().map_err(|e| e.to_string())?;
        rt.load_artifact(&model, &artifacts_dir).map_err(|e| e.to_string())?;
        Ok(Box::new(PjrtEngine { rt, model: model.clone() }) as Box<dyn Engine>)
    })
}

/// Engine factory for the micro-interpreter path (MCU-faithful execution
/// inside an SRAM-sized arena with defragmentation).
pub fn interp_engine_factory(
    graph: crate::graph::Graph,
    seed: u64,
    arena_bytes: usize,
) -> EngineFactory {
    let g = Arc::new(graph);
    Arc::new(move |_worker| {
        struct InterpEngine {
            g: Arc<crate::graph::Graph>,
            ws: crate::interp::WeightStore,
            arena_bytes: usize,
        }
        impl Engine for InterpEngine {
            fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>, String> {
                let interp = crate::interp::Interpreter::new(
                    &self.g,
                    self.ws.clone(),
                    crate::interp::ExecConfig::with_capacity(self.arena_bytes),
                );
                let r = interp
                    .run(&[crate::interp::TensorData::F32(input.to_vec())])
                    .map_err(|e| e.to_string())?;
                r.outputs[0]
                    .as_f32()
                    .map(|v| v.to_vec())
                    .ok_or_else(|| "non-f32 output".to_string())
            }
        }
        let ws = crate::interp::WeightStore::seeded_f32(&g, seed);
        Ok(Box::new(InterpEngine { g: g.clone(), ws, arena_bytes }) as Box<dyn Engine>)
    })
}

/// Coordinator configuration.
#[derive(Clone)]
pub struct ServeConfig {
    /// Worker threads (each with its own engine instance).
    pub workers: usize,
    /// Maximum requests a worker drains per queue lock (micro-batch).
    pub max_batch: usize,
    /// How long a worker waits to fill a batch once one request is pending.
    pub max_wait: Duration,
    /// Queue depth limit; beyond it, submissions are rejected
    /// (backpressure).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

struct Job {
    input: Vec<f32>,
    reply: mpsc::Sender<Result<Vec<f32>, String>>,
    enqueued: Instant,
}

#[derive(Default)]
struct Metrics {
    e2e: LatencyHist,
    exec: LatencyHist,
    queue: LatencyHist,
    batches: u64,
    batched_requests: u64,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    notify: Condvar,
    stop: AtomicBool,
    metrics: Mutex<Metrics>,
    rejected: AtomicU64,
    queue_cap: usize,
}

/// Latency/throughput snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub completed: u64,
    pub rejected: u64,
    pub mean_e2e_us: f64,
    pub p50_e2e_us: f64,
    pub p95_e2e_us: f64,
    pub p99_e2e_us: f64,
    pub mean_exec_us: f64,
    pub mean_queue_us: f64,
    /// Mean requests per drained batch (batching effectiveness).
    pub mean_batch: f64,
}

/// The serving coordinator.
pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    started: Instant,
}

impl Coordinator {
    /// Start `config.workers` threads, each constructing its engine via
    /// `factory`. Fails if any engine fails to construct.
    pub fn start(config: ServeConfig, factory: EngineFactory) -> Result<Coordinator> {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            stop: AtomicBool::new(false),
            metrics: Mutex::new(Metrics::default()),
            rejected: AtomicU64::new(0),
            queue_cap: config.queue_cap,
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut workers = Vec::with_capacity(config.workers);
        for w in 0..config.workers {
            let shared = shared.clone();
            let factory = factory.clone();
            let ready = ready_tx.clone();
            let max_batch = config.max_batch;
            let max_wait = config.max_wait;
            workers.push(std::thread::spawn(move || {
                let mut engine = match factory(w) {
                    Ok(e) => {
                        let _ = ready.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                worker_loop(&shared, engine.as_mut(), max_batch, max_wait);
            }));
        }
        drop(ready_tx);
        for _ in 0..config.workers {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died during startup"))?
                .map_err(|e| anyhow!("engine construction failed: {e}"))?;
        }
        Ok(Coordinator { shared, workers, started: Instant::now() })
    }

    /// Submit a request; returns a receiver for the reply. Errs immediately
    /// when the queue is full (backpressure).
    pub fn submit(&self, input: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>, String>>> {
        let (tx, rx) = mpsc::channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.len() >= self.shared.queue_cap {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!("queue full ({} pending)", q.len()));
            }
            q.push_back(Job { input, reply: tx, enqueued: Instant::now() });
        }
        self.shared.notify.notify_one();
        Ok(rx)
    }

    /// Blocking convenience wrapper around [`submit`](Self::submit).
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>> {
        let rx = self.submit(input)?;
        rx.recv()
            .map_err(|_| anyhow!("worker dropped reply"))?
            .map_err(|e| anyhow!("inference failed: {e}"))
    }

    /// Current metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        let m = self.shared.metrics.lock().unwrap();
        MetricsSnapshot {
            completed: m.e2e.count(),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            mean_e2e_us: m.e2e.mean_us(),
            p50_e2e_us: m.e2e.percentile_us(50.0),
            p95_e2e_us: m.e2e.percentile_us(95.0),
            p99_e2e_us: m.e2e.percentile_us(99.0),
            mean_exec_us: m.exec.mean_us(),
            mean_queue_us: m.queue.mean_us(),
            mean_batch: if m.batches == 0 {
                0.0
            } else {
                m.batched_requests as f64 / m.batches as f64
            },
        }
    }

    /// Requests per second since start.
    pub fn throughput_rps(&self) -> f64 {
        let done = self.metrics().completed as f64;
        done / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Stop workers and join them. Pending requests get an error reply.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.notify.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Drain leftovers with an error.
        let mut q = self.shared.queue.lock().unwrap();
        while let Some(job) = q.pop_front() {
            let _ = job.reply.send(Err("coordinator shut down".into()));
        }
    }
}

fn worker_loop(shared: &Shared, engine: &mut dyn Engine, max_batch: usize, max_wait: Duration) {
    loop {
        // Grab a batch: wait for one job, then linger up to `max_wait` for
        // more (micro-batching).
        let mut batch: Vec<Job> = Vec::with_capacity(max_batch);
        {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                if !q.is_empty() {
                    break;
                }
                let (guard, _) =
                    shared.notify.wait_timeout(q, Duration::from_millis(50)).unwrap();
                q = guard;
            }
            let deadline = Instant::now() + max_wait;
            loop {
                while batch.len() < max_batch {
                    match q.pop_front() {
                        Some(j) => batch.push(j),
                        None => break,
                    }
                }
                if batch.len() >= max_batch || Instant::now() >= deadline {
                    break;
                }
                let (guard, _) = shared
                    .notify
                    .wait_timeout(q, deadline.saturating_duration_since(Instant::now()))
                    .unwrap();
                q = guard;
                if q.is_empty() && Instant::now() >= deadline {
                    break;
                }
            }
        }

        let batch_size = batch.len() as u64;
        for job in batch {
            let queue_us = job.enqueued.elapsed().as_secs_f64() * 1e6;
            let t = Instant::now();
            let result = engine.infer(&job.input);
            let exec_us = t.elapsed().as_secs_f64() * 1e6;
            let e2e_us = job.enqueued.elapsed().as_secs_f64() * 1e6;
            {
                let mut m = shared.metrics.lock().unwrap();
                m.queue.record_us(queue_us);
                m.exec.record_us(exec_us);
                m.e2e.record_us(e2e_us);
            }
            let _ = job.reply.send(result);
        }
        let mut m = shared.metrics.lock().unwrap();
        m.batches += 1;
        m.batched_requests += batch_size;
    }
}

// ---------------------------------------------------------------------------
// TCP front-end: newline-delimited CSV floats in, CSV floats out.
// ---------------------------------------------------------------------------

/// Handle one TCP client: each line is `v0,v1,...`; the reply is
/// `OK p0,p1,...` or `ERR message`.
fn handle_client(coord: &Coordinator, stream: TcpStream) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() || line.trim() == "QUIT" {
            break;
        }
        let parsed: Result<Vec<f32>, _> =
            line.trim().split(',').map(|s| s.trim().parse::<f32>()).collect();
        let reply = match parsed {
            Err(e) => format!("ERR bad input: {e}\n"),
            Ok(input) => match coord.infer(input) {
                Ok(out) => {
                    let csv: Vec<String> = out.iter().map(|v| format!("{v}")).collect();
                    format!("OK {}\n", csv.join(","))
                }
                Err(e) => format!("ERR {e}\n"),
            },
        };
        if writer.write_all(reply.as_bytes()).is_err() {
            break;
        }
    }
}

/// Serve until `max_conns` connections have been accepted (`None` = run
/// forever). The bound address is reported through `on_ready` (useful with
/// port 0).
pub fn serve_tcp(
    coord: Arc<Coordinator>,
    addr: &str,
    max_conns: Option<usize>,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_ready(listener.local_addr()?);
    let mut handled = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        let coord = coord.clone();
        std::thread::spawn(move || handle_client(&coord, stream));
        handled += 1;
        if let Some(max) = max_conns {
            if handled >= max {
                break;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic toy engine: output = [sum, max] of the input.
    fn toy_factory() -> EngineFactory {
        Arc::new(|_w| {
            struct Toy;
            impl Engine for Toy {
                fn infer(&mut self, input: &[f32]) -> Result<Vec<f32>, String> {
                    if input.is_empty() {
                        return Err("empty input".into());
                    }
                    let sum: f32 = input.iter().sum();
                    let max = input.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    Ok(vec![sum, max])
                }
            }
            Ok(Box::new(Toy) as Box<dyn Engine>)
        })
    }

    #[test]
    fn infer_roundtrip() {
        let c = Coordinator::start(ServeConfig::default(), toy_factory()).unwrap();
        let out = c.infer(vec![1.0, 2.0, 3.0]).unwrap();
        assert_eq!(out, vec![6.0, 3.0]);
        c.shutdown();
    }

    #[test]
    fn parallel_submissions_all_complete() {
        let c = Arc::new(
            Coordinator::start(ServeConfig { workers: 4, ..Default::default() }, toy_factory())
                .unwrap(),
        );
        let mut rxs = Vec::new();
        for i in 0..200 {
            rxs.push((i, c.submit(vec![i as f32, 1.0]).unwrap()));
        }
        for (i, rx) in rxs {
            let out = rx.recv().unwrap().unwrap();
            assert_eq!(out[0], i as f32 + 1.0);
        }
        let m = c.metrics();
        assert_eq!(m.completed, 200);
        assert!(m.mean_batch >= 1.0);
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn engine_errors_propagate() {
        let c = Coordinator::start(ServeConfig::default(), toy_factory()).unwrap();
        assert!(c.infer(vec![]).is_err());
        c.shutdown();
    }

    #[test]
    fn failing_factory_fails_start() {
        let bad: EngineFactory = Arc::new(|_| Err("no backend".into()));
        assert!(Coordinator::start(ServeConfig::default(), bad).is_err());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Slow engine + tiny queue: part of the burst must be rejected.
        let slow: EngineFactory = Arc::new(|_| {
            struct Slow;
            impl Engine for Slow {
                fn infer(&mut self, _input: &[f32]) -> Result<Vec<f32>, String> {
                    std::thread::sleep(Duration::from_millis(30));
                    Ok(vec![1.0])
                }
            }
            Ok(Box::new(Slow) as Box<dyn Engine>)
        });
        let c = Coordinator::start(
            ServeConfig { workers: 1, queue_cap: 2, ..Default::default() },
            slow,
        )
        .unwrap();
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for _ in 0..20 {
            match c.submit(vec![0.0]) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        assert!(rejected > 0, "expected backpressure rejections");
        for rx in rxs {
            let _ = rx.recv();
        }
        assert_eq!(c.metrics().rejected as usize, rejected);
        c.shutdown();
    }

    #[test]
    fn interp_engine_serves_tiny_cnn() {
        let g = crate::models::tiny_cnn(crate::graph::DType::F32);
        let factory = interp_engine_factory(g, 42, 64 * 1024);
        let c =
            Coordinator::start(ServeConfig { workers: 2, ..Default::default() }, factory).unwrap();
        let input: Vec<f32> = (0..128).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
        let out = c.infer(input).unwrap();
        assert_eq!(out.len(), 3);
        assert!((out.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        c.shutdown();
    }

    #[test]
    fn tcp_roundtrip() {
        let g = crate::models::tiny_cnn(crate::graph::DType::F32);
        let factory = interp_engine_factory(g, 42, 64 * 1024);
        let c = Arc::new(Coordinator::start(ServeConfig::default(), factory).unwrap());
        let (addr_tx, addr_rx) = mpsc::channel();
        let server = {
            let c = c.clone();
            std::thread::spawn(move || {
                serve_tcp(c, "127.0.0.1:0", Some(1), move |a| {
                    let _ = addr_tx.send(a);
                })
            })
        };
        let addr = addr_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let mut stream = TcpStream::connect(addr).unwrap();
        let input: Vec<String> =
            (0..128).map(|i| format!("{}", ((i % 17) as f32 - 8.0) / 8.0)).collect();
        stream.write_all(format!("{}\n", input.join(",")).as_bytes()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "got: {line}");
        let probs: Vec<f32> = line[3..].trim().split(',').map(|s| s.parse().unwrap()).collect();
        assert_eq!(probs.len(), 3);
        stream.write_all(b"QUIT\n").unwrap();
        drop(stream);
        server.join().unwrap().unwrap();
    }
}

//! The plan-serving coordinator.
//!
//! A long-running service the fleet gateway runs: devices (or a fleet
//! manager acting for them) ask for a memory plan for `(model, board)` and
//! get back the same JSON documents `mcu-reorder optimize` produces —
//! because both run the identical [`crate::api::OptimizeRequest`]
//! pipeline. Plans are cached in an LRU ([`super::cache`]) keyed by
//! `(model content-hash, effective budget, options fingerprint)`, so a
//! cached reply is bit-identical to a fresh one. Duplicate in-flight
//! requests coalesce onto one planning job; when the bounded queue is
//! full, submissions are shed with an explicit response instead of
//! queueing unboundedly.
//!
//! ## TCP protocol (newline-delimited; see [`serve_plans_tcp`])
//!
//! ```text
//! PLAN <model> <board> [budget]   → OK <summary-json> | SHED … | ERR …
//! GET <model> <board> [budget]    → OK <plan-json> | SHED … | ERR …
//! ARTIFACT <TFLITE|C> <model> <board> [budget]
//!                                 → OK <nbytes>\n<raw bytes> | ERR …
//! UPLOAD <label> <nbytes>\n<raw bytes> → OK <hash16> | ERR …
//! STATS                           → OK <stats-json>
//! BOARDS                          → OK <boards-json>
//! MODELS                          → OK <name,name,…>
//! QUIT / empty line               → close
//! ```
//!
//! `ARTIFACT` is download-only: it serves the deployable bytes attached
//! to an *already cached, verified* plan — the reordered `.tflite`
//! flatbuffer (`TFLITE`, upload-sourced plans only) or the generated
//! single-file C source (`C`, [`crate::codegen`]) — and never triggers
//! planning. An uncached key is an `ERR plan not cached` reply, so a
//! device cannot use the download path to bypass admission control.
//!
//! `<model>` is a zoo name or `hash:<16-hex>` naming a prior upload;
//! `<board>` is a [`crate::mcu::boards`] name (case-insensitive);
//! `[budget]` is an explicit SRAM budget in bytes (default: the board's
//! SRAM). A request whose best split+elided peak still misses an
//! *explicit* budget gets `ERR infeasible: …`; board-default requests
//! always return the best achievable plan.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::cache::{PlanCache, PlanCacheStats, PlanKey};
use crate::api::{fnv64, ModelSource, OptimizeRequest, SCHEMA_VERSION};
use crate::graph::DType;
use crate::mcu::{boards, Board};
use crate::models;
use crate::trace::Event;
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::stats::LatencyHist;

/// Plan-service configuration.
#[derive(Clone)]
pub struct PlanServeConfig {
    /// Planner worker threads.
    pub workers: usize,
    /// Pending-job limit; beyond it, submissions are shed.
    pub queue_cap: usize,
    /// LRU plan-cache capacity (entries).
    pub cache_cap: usize,
    /// Longest accepted protocol line.
    pub max_line_bytes: usize,
    /// Largest accepted `.tflite` upload.
    pub max_upload_bytes: usize,
    /// Split/elide search configuration applied to every plan (part of
    /// the cache key via the options fingerprint).
    pub split: crate::split::SplitOptions,
    /// Record cache/shed telemetry events ([`PlanService::take_events`]).
    pub trace: bool,
}

impl Default for PlanServeConfig {
    fn default() -> Self {
        PlanServeConfig {
            workers: 2,
            queue_cap: 64,
            cache_cap: 128,
            max_line_bytes: 4096,
            max_upload_bytes: 8 * 1024 * 1024,
            split: crate::split::SplitOptions::default(),
            trace: false,
        }
    }
}

/// How a request names its model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelRef {
    /// A zoo model by name (int8, the MCU deployment dtype).
    Zoo(String),
    /// A prior upload, by its content hash.
    Uploaded(u64),
}

/// One plan request from the fleet.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub model: ModelRef,
    pub board: &'static Board,
    /// Explicit SRAM budget; `None` plans against the board's SRAM.
    pub budget: Option<usize>,
}

/// A computed plan as the service stores and serves it. `summary` and
/// `json` are the exact serialized documents — byte-identical between a
/// cache hit and a fresh computation.
pub struct CachedPlan {
    pub key: PlanKey,
    pub model: String,
    pub board: &'static str,
    /// Effective budget the plan was computed under.
    pub budget: usize,
    /// Best (split+elided) peak in bytes.
    pub peak_bytes: usize,
    pub reordered_peak: usize,
    pub segments: usize,
    /// Deploy verdict at the best peak on the target board.
    pub fits: bool,
    /// Best peak ≤ effective budget.
    pub budget_met: bool,
    pub summary: Arc<String>,
    pub json: Arc<String>,
    /// The plan's report passed the independent static verifier
    /// ([`crate::verify`]) when it was computed. [`PlanService::submit`]
    /// refuses to serve a cached plan without this — an unverified entry
    /// is treated as a miss and re-planned.
    pub verified: bool,
    /// Reordered `.tflite` bytes (`ARTIFACT TFLITE`); `None` for zoo
    /// models, which have no flatbuffer source.
    pub tflite: Option<Arc<Vec<u8>>>,
    /// Generated single-file C source (`ARTIFACT C`,
    /// [`crate::codegen::Artifact::single_file`]); `None` when the plan's
    /// graph is outside the codegen-supported surface.
    pub c_source: Option<Arc<String>>,
}

/// Why a request was not served.
#[derive(Clone, Debug)]
pub enum PlanError {
    /// Admission control: the planning queue is full.
    Shed { depth: usize },
    /// The model cannot meet the explicitly requested budget even
    /// split+elided.
    Infeasible { model: String, peak: usize, budget: usize },
    /// Bad request (unknown model/upload, unparsable flatbuffer, …).
    Invalid(String),
    /// The planner itself failed.
    Internal(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Shed { depth } => write!(f, "queue full ({depth} pending)"),
            PlanError::Infeasible { model, peak, budget } => write!(
                f,
                "infeasible: {model} needs {peak} B > budget {budget} B even split+elided"
            ),
            PlanError::Invalid(msg) => write!(f, "{msg}"),
            PlanError::Internal(msg) => write!(f, "planning failed: {msg}"),
        }
    }
}

type PlanReply = std::result::Result<Arc<CachedPlan>, PlanError>;

/// Outcome of a non-blocking [`PlanService::submit`].
pub enum Submission {
    /// Cache hit — the plan is immediately available.
    Ready(Arc<CachedPlan>),
    /// Queued (or coalesced onto an in-flight job); await the receiver.
    Pending(mpsc::Receiver<PlanReply>),
    /// Shed by admission control.
    Shed { depth: usize },
}

struct Upload {
    label: String,
    bytes: Arc<Vec<u8>>,
}

struct Job {
    key: PlanKey,
    request: OptimizeRequest,
    enqueued: Instant,
}

#[derive(Default)]
struct ServiceMetrics {
    served: u64,
    shed: u64,
    errors: u64,
    uploads: u64,
    coalesced: u64,
    infeasible: u64,
    queue_peak: usize,
    latency: LatencyHist,
}

struct State {
    cache: PlanCache<Arc<CachedPlan>>,
    uploads: HashMap<u64, Upload>,
    /// Memoized content hashes of zoo models (stable per process).
    zoo_hashes: HashMap<String, u64>,
    queue: VecDeque<Job>,
    /// Waiters per in-flight plan key (request coalescing).
    inflight: HashMap<PlanKey, Vec<mpsc::Sender<PlanReply>>>,
    metrics: ServiceMetrics,
    events: Vec<Event>,
    trace: bool,
}

/// Counter snapshot ([`PlanService::stats`]).
#[derive(Clone, Debug)]
pub struct PlanServiceStats {
    /// Plans handed out (cache hits + completed planning jobs, counted
    /// once per waiter).
    pub served: u64,
    pub shed: u64,
    pub errors: u64,
    pub uploads: u64,
    /// Requests coalesced onto an already-in-flight planning job.
    pub coalesced: u64,
    /// Explicit-budget requests whose best plan missed the budget.
    pub infeasible: u64,
    pub queue_depth: usize,
    pub queue_peak: usize,
    pub cache: PlanCacheStats,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
}

/// The plan-serving coordinator. Create with [`PlanService::start`]; share
/// via `Arc`.
pub struct PlanService {
    cfg: PlanServeConfig,
    state: Mutex<State>,
    notify: Condvar,
    stop: AtomicBool,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl PlanService {
    fn new(cfg: PlanServeConfig) -> PlanService {
        let trace = cfg.trace;
        PlanService {
            state: Mutex::new(State {
                cache: PlanCache::new(cfg.cache_cap),
                uploads: HashMap::new(),
                zoo_hashes: HashMap::new(),
                queue: VecDeque::new(),
                inflight: HashMap::new(),
                metrics: ServiceMetrics::default(),
                events: Vec::new(),
                trace,
            }),
            notify: Condvar::new(),
            stop: AtomicBool::new(false),
            workers: Mutex::new(Vec::new()),
            cfg,
        }
    }

    /// Start the service with `cfg.workers` planner threads.
    pub fn start(cfg: PlanServeConfig) -> Arc<PlanService> {
        let svc = Arc::new(PlanService::new(cfg));
        let n = svc.cfg.workers.max(1);
        {
            let mut handles = svc.workers.lock().unwrap();
            for _ in 0..n {
                let s = svc.clone();
                handles.push(std::thread::spawn(move || s.worker_loop()));
            }
        }
        svc
    }

    /// Start with no workers: submissions queue (or shed) but never
    /// complete. Used to test admission control deterministically.
    pub fn start_paused(cfg: PlanServeConfig) -> Arc<PlanService> {
        Arc::new(PlanService::new(cfg))
    }

    pub fn config(&self) -> &PlanServeConfig {
        &self.cfg
    }

    /// Register a `.tflite` model (validated by parse + import). Returns
    /// the content hash devices use as `hash:<16-hex>`.
    pub fn upload(&self, label: String, bytes: Vec<u8>) -> std::result::Result<u64, PlanError> {
        if bytes.len() > self.cfg.max_upload_bytes {
            return Err(PlanError::Invalid(format!(
                "upload too large: {} B (max {} B)",
                bytes.len(),
                self.cfg.max_upload_bytes
            )));
        }
        let model = crate::tflite::Model::parse(&bytes)
            .map_err(|e| PlanError::Invalid(format!("{label}: not a loadable TFLite model: {e}")))?;
        crate::tflite::import(&model).map_err(|e| PlanError::Invalid(format!("{label}: {e}")))?;
        let hash = fnv64(&bytes);
        let mut st = self.state.lock().unwrap();
        st.uploads.insert(hash, Upload { label, bytes: Arc::new(bytes) });
        st.metrics.uploads += 1;
        Ok(hash)
    }

    fn resolve_model_ref(
        &self,
        model: &ModelRef,
    ) -> std::result::Result<(ModelSource, u64), PlanError> {
        match model {
            ModelRef::Zoo(name) => {
                let memo = self.state.lock().unwrap().zoo_hashes.get(name).copied();
                let source = ModelSource::Zoo { name: name.clone(), dtype: DType::I8 };
                let hash = match memo {
                    Some(h) => h,
                    None => {
                        let resolved = source
                            .resolve()
                            .map_err(|e| PlanError::Invalid(format!("{e:#}")))?;
                        let h = resolved.content_hash;
                        self.state.lock().unwrap().zoo_hashes.insert(name.clone(), h);
                        h
                    }
                };
                Ok((source, hash))
            }
            ModelRef::Uploaded(hash) => {
                let st = self.state.lock().unwrap();
                match st.uploads.get(hash) {
                    Some(u) => Ok((
                        ModelSource::TfliteBytes {
                            label: u.label.clone(),
                            bytes: u.bytes.clone(),
                        },
                        *hash,
                    )),
                    None => Err(PlanError::Invalid(format!(
                        "unknown upload {hash:016x}; UPLOAD it first"
                    ))),
                }
            }
        }
    }

    /// Cache-only lookup for `ARTIFACT`: resolves the same key as
    /// [`Self::submit`] but never plans, never queues and never sheds —
    /// an absent (or unverified) entry is simply `None`. Downloads are a
    /// read-side path; a device cannot use them to bypass admission
    /// control.
    pub fn cached(
        &self,
        req: &PlanRequest,
    ) -> std::result::Result<Option<Arc<CachedPlan>>, PlanError> {
        let effective = req.budget.unwrap_or(req.board.sram_bytes);
        let (source, model_hash) = self.resolve_model_ref(&req.model)?;
        let request = OptimizeRequest {
            source,
            budget: Some(effective),
            board: req.board,
            split: Some(self.cfg.split.clone()),
            compare_materialized: false,
            trace: false,
        };
        let key = PlanKey { model_hash, budget: effective, opts_fp: request.options_fingerprint() };
        let mut st = self.state.lock().unwrap();
        Ok(st.cache.get(&key).filter(|p| p.verified))
    }

    /// Non-blocking admission: cache hit → `Ready`, otherwise enqueue (or
    /// coalesce) → `Pending`, or shed when the queue is full.
    pub fn submit(&self, req: &PlanRequest) -> std::result::Result<Submission, PlanError> {
        let effective = req.budget.unwrap_or(req.board.sram_bytes);
        let (source, model_hash) = self.resolve_model_ref(&req.model)?;
        let label = source.label().to_string();
        let request = OptimizeRequest {
            source,
            budget: Some(effective),
            board: req.board,
            split: Some(self.cfg.split.clone()),
            compare_materialized: false,
            trace: false,
        };
        let key = PlanKey { model_hash, budget: effective, opts_fp: request.options_fingerprint() };

        let mut st = self.state.lock().unwrap();
        // Proof-carrying gate: only certified plans leave the cache. An
        // unverified entry (impossible via `run()`, which re-certifies
        // every report, but cheap to enforce) falls through to a re-plan.
        if let Some(plan) = st.cache.get(&key).filter(|p| p.verified) {
            if st.trace {
                st.events.push(Event::PlanCacheLookup {
                    model: label,
                    board: req.board.name.to_string(),
                    hit: true,
                });
            }
            st.metrics.served += 1;
            return Ok(Submission::Ready(plan));
        }
        if st.trace {
            st.events.push(Event::PlanCacheLookup {
                model: label,
                board: req.board.name.to_string(),
                hit: false,
            });
        }
        let (tx, rx) = mpsc::channel();
        if let Some(waiters) = st.inflight.get_mut(&key) {
            waiters.push(tx);
            st.metrics.coalesced += 1;
            return Ok(Submission::Pending(rx));
        }
        if st.queue.len() >= self.cfg.queue_cap {
            let depth = st.queue.len();
            st.metrics.shed += 1;
            if st.trace {
                st.events.push(Event::PlanShed { depth });
            }
            return Ok(Submission::Shed { depth });
        }
        st.queue.push_back(Job { key, request, enqueued: Instant::now() });
        let depth = st.queue.len();
        st.metrics.queue_peak = st.metrics.queue_peak.max(depth);
        st.inflight.insert(key, vec![tx]);
        drop(st);
        self.notify.notify_one();
        Ok(Submission::Pending(rx))
    }

    /// Blocking plan request. An *explicit* budget that the best plan
    /// cannot meet is an [`PlanError::Infeasible`] error; board-default
    /// requests always return the best achievable plan.
    pub fn plan(&self, req: &PlanRequest) -> PlanReply {
        let plan = match self.submit(req)? {
            Submission::Ready(p) => p,
            Submission::Shed { depth } => return Err(PlanError::Shed { depth }),
            Submission::Pending(rx) => rx
                .recv()
                .map_err(|_| PlanError::Internal("planner dropped reply".to_string()))??,
        };
        if req.budget.is_some() && !plan.budget_met {
            self.state.lock().unwrap().metrics.infeasible += 1;
            return Err(PlanError::Infeasible {
                model: plan.model.clone(),
                peak: plan.peak_bytes,
                budget: plan.budget,
            });
        }
        Ok(plan)
    }

    fn worker_loop(&self) {
        loop {
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if self.stop.load(Ordering::SeqCst) {
                        return;
                    }
                    if let Some(j) = st.queue.pop_front() {
                        break j;
                    }
                    let (guard, _) =
                        self.notify.wait_timeout(st, Duration::from_millis(50)).unwrap();
                    st = guard;
                }
            };
            // Plan outside the lock — this is the expensive part.
            let result = job.request.run();
            let reply: PlanReply = match result {
                Ok(report) => {
                    let best = report.best_peak();
                    // Deployable artifacts ride on the cache entry so
                    // `ARTIFACT` downloads never re-plan: the reordered
                    // flatbuffer (`.tflite` sources only) and the
                    // generated C. Either may legitimately be absent;
                    // the download path reports that per request.
                    let tflite = report.reordered_tflite_bytes().ok().map(Arc::new);
                    let c_source = crate::codegen::weights_for_report(&report)
                        .and_then(|ws| crate::codegen::generate(&report, &ws, &report.model))
                        .ok()
                        .map(|a| Arc::new(a.single_file()));
                    Ok(Arc::new(CachedPlan {
                        key: job.key,
                        model: report.model.clone(),
                        board: report.board.name,
                        budget: job.key.budget,
                        peak_bytes: best,
                        reordered_peak: report.reordered.peak_bytes,
                        segments: report
                            .split
                            .as_ref()
                            .map(|s| s.outcome.steps.len())
                            .unwrap_or(0),
                        fits: report.deploy_at(best).fits_sram,
                        budget_met: best <= job.key.budget,
                        summary: Arc::new(report.summary_json().to_string()),
                        json: Arc::new(report.to_json().to_string()),
                        verified: report.verified,
                        tflite,
                        c_source,
                    }))
                }
                Err(e) => Err(PlanError::Internal(format!("{e:#}"))),
            };
            let waiters = {
                let mut st = self.state.lock().unwrap();
                let waiters = st.inflight.remove(&job.key).unwrap_or_default();
                match &reply {
                    Ok(plan) => {
                        if let Some((_, victim)) = st.cache.insert(job.key, plan.clone()) {
                            if st.trace {
                                st.events.push(Event::PlanCacheEvict {
                                    model: victim.model.clone(),
                                    board: victim.board.to_string(),
                                });
                            }
                        }
                        st.metrics.served += waiters.len() as u64;
                    }
                    Err(_) => st.metrics.errors += waiters.len() as u64,
                }
                st.metrics
                    .latency
                    .record_us(job.enqueued.elapsed().as_secs_f64() * 1e6);
                waiters
            };
            for tx in waiters {
                let _ = tx.send(reply.clone());
            }
        }
    }

    pub fn stats(&self) -> PlanServiceStats {
        let st = self.state.lock().unwrap();
        PlanServiceStats {
            served: st.metrics.served,
            shed: st.metrics.shed,
            errors: st.metrics.errors,
            uploads: st.metrics.uploads,
            coalesced: st.metrics.coalesced,
            infeasible: st.metrics.infeasible,
            queue_depth: st.queue.len(),
            queue_peak: st.metrics.queue_peak,
            cache: st.cache.stats(),
            mean_latency_us: st.metrics.latency.mean_us(),
            p50_latency_us: st.metrics.latency.percentile_us(50.0),
            p99_latency_us: st.metrics.latency.percentile_us(99.0),
        }
    }

    /// The `STATS` document.
    pub fn stats_json(&self) -> Json {
        let s = self.stats();
        Json::obj(vec![
            ("schema_version", Json::Num(SCHEMA_VERSION as f64)),
            ("served", Json::Num(s.served as f64)),
            ("shed", Json::Num(s.shed as f64)),
            ("errors", Json::Num(s.errors as f64)),
            ("uploads", Json::Num(s.uploads as f64)),
            ("coalesced", Json::Num(s.coalesced as f64)),
            ("infeasible", Json::Num(s.infeasible as f64)),
            ("queue_depth", Json::Num(s.queue_depth as f64)),
            ("queue_peak", Json::Num(s.queue_peak as f64)),
            (
                "cache",
                Json::obj(vec![
                    ("hits", Json::Num(s.cache.hits as f64)),
                    ("misses", Json::Num(s.cache.misses as f64)),
                    ("evictions", Json::Num(s.cache.evictions as f64)),
                    ("entries", Json::Num(s.cache.entries as f64)),
                    ("cap", Json::Num(s.cache.cap as f64)),
                ]),
            ),
            (
                "latency",
                Json::obj(vec![
                    ("mean_us", Json::Num(s.mean_latency_us)),
                    ("p50_us", Json::Num(s.p50_latency_us)),
                    ("p99_us", Json::Num(s.p99_latency_us)),
                ]),
            ),
        ])
    }

    /// Drain recorded telemetry events (empty unless `cfg.trace`).
    pub fn take_events(&self) -> Vec<Event> {
        std::mem::take(&mut self.state.lock().unwrap().events)
    }

    /// Stop workers and fail any queued jobs.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.notify.notify_all();
        let handles: Vec<JoinHandle<()>> =
            self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        let (jobs, waiters): (Vec<Job>, Vec<Vec<mpsc::Sender<PlanReply>>>) = {
            let mut st = self.state.lock().unwrap();
            let jobs: Vec<Job> = st.queue.drain(..).collect();
            let waiters = jobs
                .iter()
                .map(|j| st.inflight.remove(&j.key).unwrap_or_default())
                .collect();
            (jobs, waiters)
        };
        drop(jobs);
        for txs in waiters {
            for tx in txs {
                let _ = tx.send(Err(PlanError::Internal("service shut down".to_string())));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// TCP front-end.
// ---------------------------------------------------------------------------

enum LineError {
    TooLong,
    Closed,
    Io,
}

/// Read one `\n`-terminated line, never buffering more than `max` bytes.
/// Oversized lines are drained to their newline and reported as
/// [`LineError::TooLong`] so the connection stays usable.
fn read_line_capped<R: BufRead>(
    reader: &mut R,
    max: usize,
) -> std::result::Result<String, LineError> {
    let mut buf: Vec<u8> = Vec::new();
    let mut over = false;
    loop {
        let (consume, done) = {
            let data = match reader.fill_buf() {
                Ok(d) => d,
                Err(_) => return Err(LineError::Io),
            };
            if data.is_empty() {
                if buf.is_empty() && !over {
                    return Err(LineError::Closed);
                }
                (0, true)
            } else {
                match data.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        if !over {
                            buf.extend_from_slice(&data[..i]);
                        }
                        (i + 1, true)
                    }
                    None => {
                        if !over {
                            buf.extend_from_slice(data);
                        }
                        (data.len(), false)
                    }
                }
            }
        };
        reader.consume(consume);
        if buf.len() > max {
            over = true;
            buf.clear();
        }
        if done {
            if over {
                return Err(LineError::TooLong);
            }
            return Ok(String::from_utf8_lossy(&buf).into_owned());
        }
    }
}

fn parse_model_ref(token: &str) -> std::result::Result<ModelRef, String> {
    match token.strip_prefix("hash:") {
        Some(hex) => u64::from_str_radix(hex, 16)
            .map(ModelRef::Uploaded)
            .map_err(|_| format!("bad model hash {hex:?} (want 16 hex digits)")),
        None => Ok(ModelRef::Zoo(token.to_string())),
    }
}

fn plan_request_from(parts: &[&str]) -> std::result::Result<PlanRequest, String> {
    if parts.len() < 3 || parts.len() > 4 {
        return Err(format!("usage: {} <model> <board> [budget]", parts[0]));
    }
    let model = parse_model_ref(parts[1])?;
    let board = boards::by_name(parts[2]).ok_or_else(|| {
        let names: Vec<&str> = boards::ALL_BOARDS.iter().map(|b| b.name).collect();
        format!("unknown board {:?}; try: {}", parts[2], names.join(", "))
    })?;
    let budget = match parts.get(3) {
        Some(s) => Some(s.parse::<usize>().map_err(|_| format!("bad budget {s:?}"))?),
        None => None,
    };
    Ok(PlanRequest { model, board, budget })
}

/// `ARTIFACT <TFLITE|C> <model> <board> [budget]`: serve the deployable
/// bytes riding on an already cached, verified plan. Download-only — an
/// uncached key is an error, never a planning trigger.
fn artifact_reply(svc: &Arc<PlanService>, parts: &[&str]) -> Vec<u8> {
    if parts.len() < 4 || parts.len() > 5 {
        return b"ERR usage: ARTIFACT <TFLITE|C> <model> <board> [budget]\n".to_vec();
    }
    let kind = parts[1].to_ascii_uppercase();
    if kind != "TFLITE" && kind != "C" {
        return format!("ERR unknown artifact kind {:?} (TFLITE|C)\n", parts[1]).into_bytes();
    }
    // Key tokens in PLAN position: ARTIFACT <kind> <model> <board> [budget].
    let mut key_parts: Vec<&str> = vec![parts[0]];
    key_parts.extend_from_slice(&parts[2..]);
    let req = match plan_request_from(&key_parts) {
        Ok(r) => r,
        Err(msg) => return format!("ERR {msg}\n").into_bytes(),
    };
    let plan = match svc.cached(&req) {
        Ok(Some(p)) => p,
        Ok(None) => {
            return format!(
                "ERR plan not cached for {} on {}; PLAN it first\n",
                parts[2], parts[3]
            )
            .into_bytes()
        }
        Err(e) => return format!("ERR {e}\n").into_bytes(),
    };
    let payload: Option<Vec<u8>> = match kind.as_str() {
        "TFLITE" => plan.tflite.as_ref().map(|b| b.as_ref().clone()),
        _ => plan.c_source.as_ref().map(|s| s.as_bytes().to_vec()),
    };
    match payload {
        Some(bytes) => {
            let mut out = format!("OK {}\n", bytes.len()).into_bytes();
            out.extend_from_slice(&bytes);
            out
        }
        None if kind == "TFLITE" => {
            format!("ERR no .tflite source for {} (zoo models have no flatbuffer)\n", parts[2])
                .into_bytes()
        }
        None => format!("ERR no C artifact for {} (unsupported graph surface)\n", parts[2])
            .into_bytes(),
    }
}

/// Handle one protocol line. Returns the reply (raw bytes — `ARTIFACT`
/// replies carry a binary body) and whether to close the connection
/// afterwards.
fn dispatch_line<R: BufRead>(
    svc: &Arc<PlanService>,
    line: &str,
    reader: &mut R,
) -> (Vec<u8>, bool) {
    let parts: Vec<&str> = line.split_whitespace().collect();
    match parts[0].to_ascii_uppercase().as_str() {
        cmd @ ("PLAN" | "GET") => match plan_request_from(&parts) {
            Err(msg) => (format!("ERR {msg}\n").into_bytes(), false),
            Ok(req) => match svc.plan(&req) {
                Ok(plan) => {
                    let doc = if cmd == "GET" { &plan.json } else { &plan.summary };
                    (format!("OK {doc}\n").into_bytes(), false)
                }
                Err(PlanError::Shed { depth }) => {
                    (format!("SHED queue full ({depth} pending)\n").into_bytes(), false)
                }
                Err(e) => (format!("ERR {e}\n").into_bytes(), false),
            },
        },
        "ARTIFACT" => (artifact_reply(svc, &parts), false),
        "UPLOAD" => {
            if parts.len() != 3 {
                return (b"ERR usage: UPLOAD <label> <nbytes>\n".to_vec(), false);
            }
            let n: usize = match parts[2].parse() {
                Ok(n) => n,
                Err(_) => {
                    return (format!("ERR bad byte count {:?}\n", parts[2]).into_bytes(), false)
                }
            };
            if n > svc.cfg.max_upload_bytes {
                // The body cannot be skipped without reading it; close.
                return (
                    format!(
                        "ERR upload too large: {n} B (max {} B)\n",
                        svc.cfg.max_upload_bytes
                    )
                    .into_bytes(),
                    true,
                );
            }
            let mut bytes = vec![0u8; n];
            if reader.read_exact(&mut bytes).is_err() {
                return (b"ERR short upload body\n".to_vec(), true);
            }
            match svc.upload(parts[1].to_string(), bytes) {
                Ok(h) => (format!("OK {h:016x}\n").into_bytes(), false),
                Err(e) => (format!("ERR {e}\n").into_bytes(), false),
            }
        }
        "STATS" => (format!("OK {}\n", svc.stats_json().to_string()).into_bytes(), false),
        "BOARDS" => {
            let arr = Json::Arr(
                boards::ALL_BOARDS
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("name", Json::Str(b.name.to_string())),
                            ("sram_bytes", Json::Num(b.sram_bytes as f64)),
                        ])
                    })
                    .collect(),
            );
            (format!("OK {}\n", arr.to_string()).into_bytes(), false)
        }
        "MODELS" => (format!("OK {}\n", models::MODEL_NAMES.join(",")).into_bytes(), false),
        other => (
            format!(
                "ERR unknown command {other:?} (PLAN|GET|ARTIFACT|UPLOAD|STATS|BOARDS|MODELS|QUIT)\n"
            )
            .into_bytes(),
            false,
        ),
    }
}

fn handle_plan_client(svc: &Arc<PlanService>, stream: TcpStream) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_line_capped(&mut reader, svc.cfg.max_line_bytes) {
            Ok(l) => l,
            Err(LineError::TooLong) => {
                let msg = format!("ERR line too long (max {} B)\n", svc.cfg.max_line_bytes);
                if writer.write_all(msg.as_bytes()).is_err() {
                    return;
                }
                continue;
            }
            Err(_) => return,
        };
        let line = line.trim();
        if line.is_empty() || line == "QUIT" {
            return;
        }
        let (reply, close) = dispatch_line(svc, line, &mut reader);
        if writer.write_all(&reply).is_err() {
            return;
        }
        if close {
            return;
        }
    }
}

/// Serve the plan protocol until `max_conns` connections have been
/// accepted (`None` = run forever). The bound address is reported through
/// `on_ready` (useful with port 0).
pub fn serve_plans_tcp(
    svc: Arc<PlanService>,
    addr: &str,
    max_conns: Option<usize>,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    on_ready(listener.local_addr()?);
    let mut handled = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        let svc = svc.clone();
        std::thread::spawn(move || handle_plan_client(&svc, stream));
        handled += 1;
        if let Some(max) = max_conns {
            if handled >= max {
                break;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> PlanServeConfig {
        PlanServeConfig {
            workers: 1,
            split: crate::split::SplitOptions::quick(),
            ..Default::default()
        }
    }

    #[test]
    fn zoo_plan_roundtrip_and_cache_hit_is_bit_identical() {
        let svc = PlanService::start(quick_cfg());
        let req = PlanRequest {
            model: ModelRef::Zoo("figure1".to_string()),
            board: &crate::mcu::NUCLEO_F767ZI,
            budget: None,
        };
        let a = svc.plan(&req).unwrap();
        let b = svc.plan(&req).unwrap();
        assert_eq!(*a.json, *b.json);
        assert_eq!(*a.summary, *b.summary);
        let s = svc.stats();
        assert_eq!(s.served, 2);
        assert_eq!(s.cache.hits, 1);
        assert_eq!(s.cache.misses, 1);
        svc.shutdown();
    }

    #[test]
    fn unknown_model_and_upload_are_invalid() {
        let svc = PlanService::start_paused(quick_cfg());
        let bad_zoo = PlanRequest {
            model: ModelRef::Zoo("nope".to_string()),
            board: &crate::mcu::NUCLEO_F767ZI,
            budget: None,
        };
        assert!(matches!(svc.submit(&bad_zoo), Err(PlanError::Invalid(_))));
        let bad_up = PlanRequest {
            model: ModelRef::Uploaded(0xdead),
            board: &crate::mcu::NUCLEO_F767ZI,
            budget: None,
        };
        assert!(matches!(svc.submit(&bad_up), Err(PlanError::Invalid(_))));
        svc.shutdown();
    }

    #[test]
    fn paused_service_sheds_beyond_queue_cap() {
        let cfg = PlanServeConfig { queue_cap: 1, ..quick_cfg() };
        let svc = PlanService::start_paused(cfg);
        let req = |b: usize| PlanRequest {
            model: ModelRef::Zoo("tiny".to_string()),
            board: &crate::mcu::NUCLEO_F767ZI,
            budget: Some(4_000_000 + b),
        };
        assert!(matches!(svc.submit(&req(0)), Ok(Submission::Pending(_))));
        assert!(matches!(svc.submit(&req(1)), Ok(Submission::Shed { depth: 1 })));
        assert_eq!(svc.stats().shed, 1);
        svc.shutdown();
    }

    #[test]
    fn coalesces_duplicate_inflight_requests() {
        let svc = PlanService::start_paused(quick_cfg());
        let req = PlanRequest {
            model: ModelRef::Zoo("tiny".to_string()),
            board: &crate::mcu::NUCLEO_F767ZI,
            budget: None,
        };
        assert!(matches!(svc.submit(&req), Ok(Submission::Pending(_))));
        assert!(matches!(svc.submit(&req), Ok(Submission::Pending(_))));
        let s = svc.stats();
        assert_eq!(s.coalesced, 1);
        assert_eq!(s.queue_depth, 1);
        svc.shutdown();
    }

    #[test]
    fn explicit_infeasible_budget_errors_cleanly() {
        let svc = PlanService::start(quick_cfg());
        let req = PlanRequest {
            model: ModelRef::Zoo("figure1".to_string()),
            board: &crate::mcu::NUCLEO_F767ZI,
            budget: Some(16),
        };
        match svc.plan(&req) {
            Err(PlanError::Infeasible { budget: 16, .. }) => {}
            other => panic!("expected infeasible, got {:?}", other.map(|p| p.peak_bytes)),
        }
        assert_eq!(svc.stats().infeasible, 1);
        // The same model at the board default still plans fine.
        let ok = svc
            .plan(&PlanRequest { budget: None, ..req })
            .expect("board-default request must serve");
        assert!(ok.fits);
        svc.shutdown();
    }

    #[test]
    fn oversized_line_reports_and_connection_survives() {
        let svc = PlanService::start(quick_cfg());
        let (addr_tx, addr_rx) = mpsc::channel();
        let server = {
            let svc = svc.clone();
            std::thread::spawn(move || {
                serve_plans_tcp(svc, "127.0.0.1:0", Some(1), move |a| {
                    let _ = addr_tx.send(a);
                })
            })
        };
        let addr = addr_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let mut stream = TcpStream::connect(addr).unwrap();
        let long = "X".repeat(svc.cfg.max_line_bytes + 100);
        stream.write_all(format!("{long}\n").as_bytes()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ERR line too long"), "got: {line}");
        stream.write_all(b"MODELS\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "got: {line}");
        stream.write_all(b"QUIT\n").unwrap();
        drop(stream);
        server.join().unwrap().unwrap();
        svc.shutdown();
    }
}

//! LRU plan cache.
//!
//! Keys are [`PlanKey`] — `(model content-hash, effective SRAM budget,
//! options fingerprint)` — so the cache can never conflate two requests
//! that would plan differently (see
//! [`crate::api::OptimizeRequest::options_fingerprint`]). Recency is a
//! strictly-increasing tick counter: `get` promotes, `insert` evicts the
//! minimum-tick entry when full. Because ticks never repeat, eviction
//! order is fully deterministic, which the serving bench's Python mirror
//! relies on to predict hit/miss/eviction counts exactly.

use std::collections::HashMap;

/// Identity of a cached plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// FNV-1a of the model content ([`crate::api::fnv64`]).
    pub model_hash: u64,
    /// Effective SRAM budget in bytes (explicit budget, or the board's).
    pub budget: usize,
    /// Fingerprint of board + split options + schema version.
    pub opts_fp: u64,
}

/// Hit/miss/eviction counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Configured capacity.
    pub cap: usize,
}

/// A fixed-capacity LRU map from [`PlanKey`] to a plan value.
pub struct PlanCache<V: Clone> {
    map: HashMap<PlanKey, (u64, V)>,
    tick: u64,
    cap: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl<V: Clone> PlanCache<V> {
    /// Capacity is clamped to at least 1.
    pub fn new(cap: usize) -> PlanCache<V> {
        PlanCache {
            map: HashMap::new(),
            tick: 0,
            cap: cap.max(1),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Look up a plan, promoting it to most-recently-used on hit.
    pub fn get(&mut self, key: &PlanKey) -> Option<V> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some((tick, v)) => {
                *tick = self.tick;
                self.hits += 1;
                Some(v.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or refresh) a plan. Returns the evicted entry when the
    /// cache was full and a least-recently-used victim had to go.
    pub fn insert(&mut self, key: PlanKey, value: V) -> Option<(PlanKey, V)> {
        self.tick += 1;
        if self.map.contains_key(&key) {
            self.map.insert(key, (self.tick, value));
            return None;
        }
        let mut evicted = None;
        if self.map.len() >= self.cap {
            let victim = self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| *k);
            if let Some(k) = victim {
                if let Some((_, v)) = self.map.remove(&k) {
                    self.evictions += 1;
                    evicted = Some((k, v));
                }
            }
        }
        self.map.insert(key, (self.tick, value));
        evicted
    }

    pub fn contains(&self, key: &PlanKey) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            entries: self.map.len(),
            cap: self.cap,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: u64) -> PlanKey {
        PlanKey { model_hash: n, budget: 1024, opts_fp: 7 }
    }

    #[test]
    fn hit_miss_counting() {
        let mut c: PlanCache<u32> = PlanCache::new(2);
        assert_eq!(c.get(&key(1)), None);
        c.insert(key(1), 10);
        assert_eq!(c.get(&key(1)), Some(10));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (1, 1, 0, 1));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: PlanCache<u32> = PlanCache::new(2);
        c.insert(key(1), 10);
        c.insert(key(2), 20);
        assert_eq!(c.get(&key(1)), Some(10)); // promote 1; 2 is now LRU
        let evicted = c.insert(key(3), 30);
        assert_eq!(evicted.map(|(k, v)| (k.model_hash, v)), Some((2, 20)));
        assert_eq!(c.get(&key(2)), None);
        assert_eq!(c.get(&key(1)), Some(10));
        assert_eq!(c.get(&key(3)), Some(30));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c: PlanCache<u32> = PlanCache::new(2);
        c.insert(key(1), 10);
        c.insert(key(2), 20);
        assert!(c.insert(key(1), 11).is_none()); // refresh, not a new entry
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&key(1)), Some(11));
    }

    #[test]
    fn distinct_budgets_are_distinct_keys() {
        let mut c: PlanCache<u32> = PlanCache::new(4);
        let a = PlanKey { model_hash: 1, budget: 1024, opts_fp: 7 };
        let b = PlanKey { model_hash: 1, budget: 2048, opts_fp: 7 };
        c.insert(a, 1);
        c.insert(b, 2);
        assert_eq!(c.get(&a), Some(1));
        assert_eq!(c.get(&b), Some(2));
    }

    #[test]
    fn zero_cap_clamps_to_one() {
        let mut c: PlanCache<u32> = PlanCache::new(0);
        c.insert(key(1), 10);
        assert_eq!(c.get(&key(1)), Some(10));
        c.insert(key(2), 20);
        assert_eq!(c.get(&key(1)), None);
        assert_eq!(c.stats().cap, 1);
    }
}

"""Layer-2: build the JAX forward function for a Rust-exported graph.

`build_forward(graph, backend)` walks the computation DAG (in its embedded
execution order when present) and emits a pure function
``f(*inputs) -> tuple(outputs)`` whose convolution/dense ops are the Layer-1
Pallas kernels (``backend="pallas"``, the default) or the pure-jnp oracle
(``backend="jnp"``, used to cross-check the kernels at model scale).

Weights come from the graph container (baked by ``mcu-reorder export``) and
are closed over, so the lowered HLO embeds them as constants — the NOR-Flash
analogy: parameters are immutable at inference and do not occupy SRAM.
"""

from __future__ import annotations

from typing import Callable, Dict, List

import jax.numpy as jnp

from . import graph_ir
from .kernels import conv as pallas_kernels
from .kernels import ref as jnp_kernels


def _padding_str(attrs: Dict) -> str:
    return {"same": "SAME", "valid": "VALID"}[attrs.get("padding", "same")]


def _pair(attrs: Dict, key: str):
    v = attrs[key]
    return (int(v[0]), int(v[1]))


def build_forward(
    g: graph_ir.Graph, backend: str = "pallas"
) -> Callable[..., tuple]:
    """Return ``f(*graph_inputs) -> tuple(graph_outputs)``."""
    if backend == "pallas":
        k = pallas_kernels
    elif backend == "jnp":
        k = jnp_kernels
    else:
        raise ValueError(f"unknown backend {backend!r}")

    if not g.weight_data and any(t.is_weight for t in g.tensors):
        raise ValueError("graph has weight tensors but no weight data was loaded")

    order = g.execution_order or list(range(len(g.ops)))
    weights = {tid: jnp.asarray(arr, dtype=jnp.float32) for tid, arr in g.weight_data.items()}

    def forward(*inputs):
        if len(inputs) != len(g.inputs):
            raise ValueError(f"expected {len(g.inputs)} inputs, got {len(inputs)}")
        vals: Dict[int, jnp.ndarray] = {}
        for tid, x in zip(g.inputs, inputs):
            expect = tuple(g.tensors[tid].shape)
            if tuple(x.shape) != expect:
                raise ValueError(
                    f"input {g.tensors[tid].name} expects shape {expect}, got {x.shape}"
                )
            vals[tid] = x

        for opid in order:
            op = g.ops[opid]
            ins: List[jnp.ndarray] = [vals[t] for t in op.inputs]
            a = op.attrs
            if op.kind == "Conv2D":
                w = weights[op.weights[0]]
                b = weights[op.weights[1]]
                y = k.conv2d(
                    ins[0], w, b,
                    stride=_pair(a, "stride"),
                    padding=_padding_str(a),
                    act=a.get("act", "linear"),
                )
            elif op.kind == "DepthwiseConv2D":
                w = weights[op.weights[0]]
                b = weights[op.weights[1]]
                y = k.dwconv2d(
                    ins[0], w, b,
                    stride=_pair(a, "stride"),
                    padding=_padding_str(a),
                    act=a.get("act", "linear"),
                )
            elif op.kind == "Dense":
                w = weights[op.weights[0]]
                b = weights[op.weights[1]]
                y = k.dense(ins[0], w, b, act=a.get("act", "linear"))
            elif op.kind == "Add":
                y = jnp_kernels.add(ins[0], ins[1])
            elif op.kind == "Concat":
                y = jnp_kernels.concat_channels(ins)
            elif op.kind == "Relu":
                y = jnp_kernels.relu(ins[0])
            elif op.kind == "Relu6":
                y = jnp_kernels.relu6(ins[0])
            elif op.kind == "MaxPool2D":
                y = jnp_kernels.maxpool2d(ins[0], _pair(a, "kernel"), _pair(a, "stride"), _padding_str(a))
            elif op.kind == "AvgPool2D":
                y = jnp_kernels.avgpool2d(ins[0], _pair(a, "kernel"), _pair(a, "stride"), _padding_str(a))
            elif op.kind == "GlobalAvgPool":
                y = jnp_kernels.global_avgpool(ins[0])
            elif op.kind == "Softmax":
                y = jnp_kernels.softmax(ins[0])
            elif op.kind == "Reshape":
                y = ins[0].reshape(tuple(g.tensors[op.output].shape))
            else:
                raise NotImplementedError(f"op kind {op.kind} ({op.name})")

            expect = tuple(g.tensors[op.output].shape)
            if tuple(y.shape) != expect:
                raise AssertionError(
                    f"op {op.name}: produced shape {y.shape}, graph says {expect}"
                )
            vals[op.output] = y

        return tuple(vals[t] for t in g.outputs)

    return forward

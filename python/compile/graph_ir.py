"""Load the Rust-exported model container (graph JSON + weights blob).

The Rust model zoo is the single source of truth for architectures
(`mcu-reorder export` writes `<model>.json` + `<model>.weights.bin`); this
module parses that container so the L2 JAX builder and the L1 Pallas kernels
cannot drift from the graph the coordinator schedules.

Weight blob layout: float32 little-endian, weight tensors concatenated in
tensor-id order, each in its declared shape (row-major):
  Conv2D            [kh, kw, cin, cout]   (HWIO)
  DepthwiseConv2D   [kh, kw, c]
  Dense             [in, out]
  biases            [out]
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

FORMAT = "mcu-reorder/v1"


@dataclass
class Tensor:
    id: int
    name: str
    shape: List[int]
    dtype: str
    is_weight: bool

    @property
    def elems(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclass
class Op:
    id: int
    name: str
    kind: str
    attrs: Dict
    inputs: List[int]
    weights: List[int]
    output: int


@dataclass
class Graph:
    name: str
    tensors: List[Tensor]
    ops: List[Op]
    inputs: List[int]
    outputs: List[int]
    execution_order: Optional[List[int]] = None
    weight_data: Dict[int, np.ndarray] = field(default_factory=dict)

    def tensor_by_name(self, name: str) -> Tensor:
        for t in self.tensors:
            if t.name == name:
                return t
        raise KeyError(name)


def load_graph(json_path: str, weights_path: Optional[str] = None) -> Graph:
    """Parse the model JSON (and optional weights blob) into a Graph."""
    with open(json_path) as f:
        doc = json.load(f)
    if doc.get("format") != FORMAT:
        raise ValueError(f"unsupported model format: {doc.get('format')!r}")

    tensors = [
        Tensor(
            id=t["id"],
            name=t["name"],
            shape=list(t["shape"]),
            dtype=t["dtype"],
            is_weight=bool(t["weight"]),
        )
        for t in doc["tensors"]
    ]
    for i, t in enumerate(tensors):
        if t.id != i:
            raise ValueError("tensor ids must be dense")

    ops = [
        Op(
            id=o["id"],
            name=o["name"],
            kind=o["kind"],
            attrs=o.get("attrs", {}),
            inputs=list(o["inputs"]),
            weights=list(o["weights"]),
            output=o["output"],
        )
        for o in doc["ops"]
    ]

    g = Graph(
        name=doc["name"],
        tensors=tensors,
        ops=ops,
        inputs=list(doc["inputs"]),
        outputs=list(doc["outputs"]),
        execution_order=doc.get("execution_order"),
    )

    if weights_path is not None:
        blob = np.fromfile(weights_path, dtype="<f4")
        cursor = 0
        for t in tensors:
            if not t.is_weight:
                continue
            n = t.elems
            if cursor + n > blob.size:
                raise ValueError(
                    f"weights blob too short at tensor {t.name} "
                    f"(need {cursor + n}, have {blob.size})"
                )
            g.weight_data[t.id] = blob[cursor : cursor + n].reshape(t.shape).copy()
            cursor += n
        if cursor != blob.size:
            raise ValueError(f"weights blob has {blob.size - cursor} trailing floats")
    return g

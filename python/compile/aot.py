"""AOT exporter: lower a Rust-exported model to HLO text + manifest.

HLO *text* (not a serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (invoked by `make artifacts`):

    python -m compile.aot --json artifacts/src/tiny.json \\
        --weights artifacts/src/tiny.weights.bin \\
        --out-dir artifacts --name tiny [--backend pallas]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import graph_ir, model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is essential: the default elides big
    # weight constants as "{...}", which the pinned XLA 0.5.1 text parser
    # silently parses as ZEROS — the artifact would run but with zeroed
    # weights.
    return comp.as_hlo_text(print_large_constants=True)


def export(json_path: str, weights_path: str, out_dir: str, name: str, backend: str) -> dict:
    g = graph_ir.load_graph(json_path, weights_path)
    forward = model.build_forward(g, backend=backend)

    in_specs = [
        jax.ShapeDtypeStruct(tuple(g.tensors[t].shape), jnp.float32) for t in g.inputs
    ]
    lowered = jax.jit(forward).lower(*in_specs)
    hlo = to_hlo_text(lowered)

    os.makedirs(out_dir, exist_ok=True)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    manifest = {
        "model": g.name,
        "kernels": backend,
        "inputs": [
            {
                "name": g.tensors[t].name,
                "shape": list(g.tensors[t].shape),
                "dtype": "f32",
            }
            for t in g.inputs
        ],
        "outputs": [
            {
                "name": g.tensors[t].name,
                "shape": list(g.tensors[t].shape),
                "dtype": "f32",
            }
            for t in g.outputs
        ],
    }
    man_path = os.path.join(out_dir, f"{name}.manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {hlo_path} ({len(hlo)} chars) + {man_path}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--json", required=True, help="model JSON from `mcu-reorder export`")
    p.add_argument("--weights", required=True, help="weights blob from `mcu-reorder export`")
    p.add_argument("--out-dir", required=True)
    p.add_argument("--name", required=True, help="artifact base name")
    p.add_argument("--backend", default="pallas", choices=["pallas", "jnp"])
    args = p.parse_args()
    export(args.json, args.weights, args.out_dir, args.name, args.backend)


if __name__ == "__main__":
    main()

"""Pure-jnp correctness oracle for the Pallas kernels.

Every kernel in this package has a reference implementation here, written
with `jax.lax` primitives whose semantics are well understood (TF-style SAME
padding, NHWC/HWIO layouts). The pytest suite sweeps shapes/strides/paddings
and asserts the Pallas kernels match to float32 tolerance; the Rust
micro-interpreter implements the same semantics and is cross-checked against
the lowered artifacts end-to-end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _act(y, act: str):
    if act == "linear":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "relu6":
        return jnp.clip(y, 0.0, 6.0)
    raise ValueError(f"unknown act {act!r}")


def conv2d(x, w, b, stride=(1, 1), padding="SAME", act="linear"):
    """Standard conv. x: [1,H,W,Cin], w: [kh,kw,Cin,Cout] (HWIO), b: [Cout]."""
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return _act(y + b, act)


def dwconv2d(x, w, b, stride=(1, 1), padding="SAME", act="linear"):
    """Depthwise conv (multiplier 1). x: [1,H,W,C], w: [kh,kw,C], b: [C]."""
    c = x.shape[-1]
    w4 = w.reshape(w.shape[0], w.shape[1], 1, c)
    y = lax.conv_general_dilated(
        x,
        w4,
        window_strides=stride,
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return _act(y + b, act)


def dense(x, w, b, act="linear"):
    """Fully connected. x: [1, ...] flattened, w: [in,out], b: [out]."""
    y = x.reshape(1, -1) @ w + b
    return _act(y, act)


def add(a, b):
    return a + b


def concat_channels(parts):
    return jnp.concatenate(parts, axis=-1)


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def maxpool2d(x, kernel, stride, padding="SAME"):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, kernel[0], kernel[1], 1),
        window_strides=(1, stride[0], stride[1], 1),
        padding=padding,
    )


def avgpool2d(x, kernel, stride, padding="SAME"):
    """Average pooling, divisor = number of valid taps (TFLite semantics)."""
    summed = lax.reduce_window(
        x,
        0.0,
        lax.add,
        window_dimensions=(1, kernel[0], kernel[1], 1),
        window_strides=(1, stride[0], stride[1], 1),
        padding=padding,
    )
    counts = lax.reduce_window(
        jnp.ones_like(x),
        0.0,
        lax.add,
        window_dimensions=(1, kernel[0], kernel[1], 1),
        window_strides=(1, stride[0], stride[1], 1),
        padding=padding,
    )
    return summed / counts


def global_avgpool(x):
    return jnp.mean(x, axis=(1, 2), keepdims=True)


def softmax(x):
    return jax.nn.softmax(x, axis=-1)

"""Layer-1 Pallas kernels: the convolution hot-spots of the evaluated models.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode turns each ``pallas_call``
into plain HLO that the Rust runtime's CPU client runs bit-for-bit. The
kernels are nonetheless *structured* for a real TPU lowering:

- the **pointwise (1×1) conv** — the dominant FLOP sink of MobileNet and the
  SwiftNet-style cells — is a grid of ``(TILE_HW, Cin) @ (Cin, Cout)``
  matmuls, i.e. MXU-shaped work per grid step, with the HBM↔VMEM staging
  expressed through ``BlockSpec`` row tiles;
- the **depthwise 3×3 conv** processes one output row per grid step,
  accumulating the kh×kw taps as vectorized multiply-adds over the row
  (VPU-shaped work), reading only the ``kh`` input rows it needs;
- the **general conv** (network stems) does a per-tap
  ``(W_out, Cin) @ (Cin, Cout)`` matmul per output row.

Spatial SAME padding is materialized with ``jnp.pad`` before the kernel — on
TPU that boundary is where the HBM→VMEM copy happens, and DESIGN.md
§Hardware-Adaptation discusses the VMEM budget per tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT target; see module docstring.


def same_pad(in_size: int, k: int, stride: int) -> tuple[int, int]:
    """TF-style SAME padding split (low, high)."""
    out = -(-in_size // stride)  # ceil div
    total = max((out - 1) * stride + k - in_size, 0)
    return total // 2, total - total // 2


def _out_dim(in_size: int, k: int, stride: int, padding: str) -> int:
    if padding == "SAME":
        return -(-in_size // stride)
    return (in_size - k) // stride + 1


def _act(y, act: str):
    if act == "linear":
        return y
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "relu6":
        return jnp.clip(y, 0.0, 6.0)
    raise ValueError(f"unknown act {act!r}")


def _row_tile(hw: int, target: int = 256) -> int:
    """Largest divisor of `hw` that is ≤ target (grid tiles must divide the
    array; on TPU we'd pick a multiple of 8 rows × 128 lanes)."""
    best = 1
    for d in range(1, hw + 1):
        if hw % d == 0 and d <= target:
            best = d
    return best


# ---------------------------------------------------------------------------
# Pointwise (1×1) convolution: tiled matmul.
# ---------------------------------------------------------------------------


def _pointwise_kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    """One tile: (TILE, Cin) @ (Cin, Cout) + bias, fused activation."""
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = _act(y + b_ref[...], act)


def pointwise_conv(x, w, b, stride=(1, 1), act="linear"):
    """1×1 convolution. x: [1,H,W,Cin], w: [1,1,Cin,Cout] or [Cin,Cout].

    Strided 1×1 convs subsample rows/cols first (cheap gather), then run the
    matmul grid over the remaining pixels.
    """
    if w.ndim == 4:
        w = w.reshape(w.shape[2], w.shape[3])
    _, h, wd, cin = x.shape
    if stride != (1, 1):
        x = x[:, :: stride[0], :: stride[1], :]
        _, h, wd, cin = x.shape
    cout = w.shape[1]
    hw = h * wd
    tile = _row_tile(hw)
    x2 = x.reshape(hw, cin)

    out = pl.pallas_call(
        functools.partial(_pointwise_kernel, act=act),
        grid=(hw // tile,),
        in_specs=[
            pl.BlockSpec((tile, cin), lambda i: (i, 0)),
            pl.BlockSpec((cin, cout), lambda i: (0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hw, cout), jnp.float32),
        interpret=INTERPRET,
    )(x2, w, b)
    return out.reshape(1, h, wd, cout)


# ---------------------------------------------------------------------------
# Depthwise conv: one output row per grid step.
# ---------------------------------------------------------------------------


def _dw_row_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sh, sw, w_out, act):
    """Compute one output row: accumulate kh·kw taps over the row."""
    oy = pl.program_id(0)
    xpad = x_ref[...]  # (H_pad, W_pad, C) — staged block
    c = xpad.shape[-1]
    rows = lax.dynamic_slice(
        xpad, (oy * sh, 0, 0), (kh, xpad.shape[1], c)
    )  # (kh, W_pad, C)
    acc = jnp.zeros((w_out, c), dtype=jnp.float32)
    for ky in range(kh):
        for kx in range(kw):
            span = rows[ky, kx : kx + (w_out - 1) * sw + 1 : sw, :]  # (W_out, C)
            acc = acc + span * w_ref[ky, kx, :]
    o_ref[...] = _act(acc + b_ref[...], act)[None, :, :]


def dwconv2d(x, w, b, stride=(1, 1), padding="SAME", act="linear"):
    """Depthwise conv (multiplier 1). x: [1,H,W,C], w: [kh,kw,C], b: [C]."""
    _, h, wd, c = x.shape
    kh, kw = w.shape[0], w.shape[1]
    sh, sw = stride
    h_out = _out_dim(h, kh, sh, padding)
    w_out = _out_dim(wd, kw, sw, padding)
    if padding == "SAME":
        (pt, pb), (pl_, pr) = same_pad(h, kh, sh), same_pad(wd, kw, sw)
    else:
        (pt, pb), (pl_, pr) = (0, 0), (0, 0)
    xpad = jnp.pad(x[0], ((pt, pb), (pl_, pr), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _dw_row_kernel, kh=kh, kw=kw, sh=sh, sw=sw, w_out=w_out, act=act
        ),
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(xpad.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, w_out, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out, w_out, c), jnp.float32),
        interpret=INTERPRET,
    )(xpad, w, b)
    return out.reshape(1, h_out, w_out, c)


# ---------------------------------------------------------------------------
# General conv (stems): per-tap matmul, one output row per grid step.
# ---------------------------------------------------------------------------


def _conv_row_kernel(x_ref, w_ref, b_ref, o_ref, *, kh, kw, sh, sw, w_out, act):
    oy = pl.program_id(0)
    xpad = x_ref[...]  # (H_pad, W_pad, Cin)
    cin = xpad.shape[-1]
    cout = w_ref.shape[-1]
    rows = lax.dynamic_slice(xpad, (oy * sh, 0, 0), (kh, xpad.shape[1], cin))
    acc = jnp.zeros((w_out, cout), dtype=jnp.float32)
    for ky in range(kh):
        for kx in range(kw):
            span = rows[ky, kx : kx + (w_out - 1) * sw + 1 : sw, :]  # (W_out, Cin)
            acc = acc + jnp.dot(
                span, w_ref[ky, kx, :, :], preferred_element_type=jnp.float32
            )
    o_ref[...] = _act(acc + b_ref[...], act)[None, :, :]


def conv2d(x, w, b, stride=(1, 1), padding="SAME", act="linear"):
    """Standard conv. x: [1,H,W,Cin], w: [kh,kw,Cin,Cout], b: [Cout]."""
    kh, kw = w.shape[0], w.shape[1]
    if (kh, kw) == (1, 1):
        return pointwise_conv(x, w, b, stride=stride, act=act)
    _, h, wd, cin = x.shape
    cout = w.shape[3]
    sh, sw = stride
    h_out = _out_dim(h, kh, sh, padding)
    w_out = _out_dim(wd, kw, sw, padding)
    if padding == "SAME":
        (pt, pb), (pl_, pr) = same_pad(h, kh, sh), same_pad(wd, kw, sw)
    else:
        (pt, pb), (pl_, pr) = (0, 0), (0, 0)
    xpad = jnp.pad(x[0], ((pt, pb), (pl_, pr), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _conv_row_kernel, kh=kh, kw=kw, sh=sh, sw=sw, w_out=w_out, act=act
        ),
        grid=(h_out,),
        in_specs=[
            pl.BlockSpec(xpad.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, w_out, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h_out, w_out, cout), jnp.float32),
        interpret=INTERPRET,
    )(xpad, w, b)
    return out.reshape(1, h_out, w_out, cout)


# ---------------------------------------------------------------------------
# Dense head.
# ---------------------------------------------------------------------------


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = _act(y + b_ref[...], act)


def dense(x, w, b, act="linear"):
    """Fully connected. x: [1, ...] (flattened), w: [in,out], b: [out]."""
    x2 = x.reshape(1, -1)
    n_in, n_out = w.shape
    out = pl.pallas_call(
        functools.partial(_dense_kernel, act=act),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n_in), lambda i: (0, 0)),
            pl.BlockSpec((n_in, n_out), lambda i: (0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, n_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_out), jnp.float32),
        interpret=INTERPRET,
    )(x2, w, b)
    return out

"""AOT exporter: HLO text hygiene + manifest correctness.

The critical regression here: `as_hlo_text()` must print large constants —
the default elides them as `{...}`, which the pinned XLA 0.5.1 text parser
silently interprets as ZEROED weights.
"""

import json
import os

import pytest

from compile import aot
from tests.test_model import container, SRC


@pytest.fixture(scope="module")
def tiny_export(tmp_path_factory):
    container("tiny")  # ensure the source container exists
    out = tmp_path_factory.mktemp("aot")
    manifest = aot.export(
        os.path.join(SRC, "tiny.json"),
        os.path.join(SRC, "tiny.weights.bin"),
        str(out),
        "tiny",
        "pallas",
    )
    return out, manifest


def test_no_elided_constants(tiny_export):
    out, _ = tiny_export
    hlo = (out / "tiny.hlo.txt").read_text()
    assert "constant({...})" not in hlo, "large constants were elided (zeroed weights!)"
    assert "ENTRY" in hlo


def test_manifest_shapes(tiny_export):
    out, manifest = tiny_export
    on_disk = json.loads((out / "tiny.manifest.json").read_text())
    assert on_disk == manifest
    assert manifest["model"] == "tiny-cnn"
    assert manifest["kernels"] == "pallas"
    assert manifest["inputs"][0]["shape"] == [1, 8, 8, 2]
    assert manifest["outputs"][0]["shape"] == [1, 3]


def test_hlo_has_weights_as_constants_not_params(tiny_export):
    out, _ = tiny_export
    hlo = (out / "tiny.hlo.txt").read_text()
    entry = hlo[hlo.index("ENTRY") :]
    n_params = entry.count(" parameter(")
    assert n_params == 1, f"expected only the image input as parameter, got {n_params}"

"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, strides, paddings and activations; assert_allclose
at float32 tolerance. This is the core correctness signal for the kernels
that get lowered into every AOT artifact.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as pk
from compile.kernels import ref

F32 = np.float32
settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(F32))


strides = st.sampled_from([(1, 1), (2, 2), (1, 2)])
paddings = st.sampled_from(["SAME", "VALID"])
acts = st.sampled_from(["linear", "relu", "relu6"])


@given(
    h=st.integers(3, 10),
    w=st.integers(3, 10),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    stride=strides,
    act=acts,
    seed=st.integers(0, 2**31 - 1),
)
def test_pointwise_matches_ref(h, w, cin, cout, stride, act, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, 1, h, w, cin)
    wt = rand(rng, 1, 1, cin, cout)
    b = rand(rng, cout)
    got = pk.pointwise_conv(x, wt, b, stride=stride, act=act)
    want = ref.conv2d(x, wt, b, stride, "SAME", act)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5, rtol=1e-5)


@given(
    h=st.integers(3, 10),
    w=st.integers(3, 10),
    c=st.integers(1, 8),
    k=st.sampled_from([(3, 3), (1, 3), (5, 5)]),
    stride=strides,
    padding=paddings,
    act=acts,
    seed=st.integers(0, 2**31 - 1),
)
def test_dwconv_matches_ref(h, w, c, k, stride, padding, act, seed):
    if padding == "VALID" and (h < k[0] or w < k[1]):
        return
    rng = np.random.default_rng(seed)
    x = rand(rng, 1, h, w, c)
    wt = rand(rng, k[0], k[1], c)
    b = rand(rng, c)
    got = pk.dwconv2d(x, wt, b, stride=stride, padding=padding, act=act)
    want = ref.dwconv2d(x, wt, b, stride, padding, act)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5, rtol=1e-5)


@given(
    h=st.integers(3, 9),
    w=st.integers(3, 9),
    cin=st.integers(1, 5),
    cout=st.integers(1, 5),
    stride=strides,
    padding=paddings,
    act=acts,
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(h, w, cin, cout, stride, padding, act, seed):
    if padding == "VALID" and (h < 3 or w < 3):
        return
    rng = np.random.default_rng(seed)
    x = rand(rng, 1, h, w, cin)
    wt = rand(rng, 3, 3, cin, cout)
    b = rand(rng, cout)
    got = pk.conv2d(x, wt, b, stride=stride, padding=padding, act=act)
    want = ref.conv2d(x, wt, b, stride, padding, act)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5, rtol=1e-5)


@given(
    n_in=st.integers(1, 64),
    n_out=st.integers(1, 16),
    act=acts,
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(n_in, n_out, act, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, 1, n_in)
    wt = rand(rng, n_in, n_out)
    b = rand(rng, n_out)
    got = pk.dense(x, wt, b, act=act)
    want = ref.dense(x, wt, b, act)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=1e-5, rtol=1e-5)


def test_same_pad_matches_tf_convention():
    # in=8, k=3, s=2 → out=4, total=1 → (0, 1); in=5, k=3, s=1 → (1, 1).
    assert pk.same_pad(8, 3, 2) == (0, 1)
    assert pk.same_pad(5, 3, 1) == (1, 1)
    assert pk.same_pad(4, 1, 1) == (0, 0)


def test_row_tile_divides():
    for hw in [2304, 576, 144, 36, 1, 97]:
        t = pk._row_tile(hw)
        assert hw % t == 0 and t <= 256


def test_mxu_shaped_pointwise_tile():
    # A 48x48 feature map should tile into 256-row blocks (MXU-friendly).
    assert pk._row_tile(48 * 48) == 256


@pytest.mark.parametrize("act,lo,hi", [("relu", 0.0, None), ("relu6", 0.0, 6.0)])
def test_act_bounds(act, lo, hi):
    rng = np.random.default_rng(0)
    x = rand(rng, 1, 4, 4, 3) * 10
    wt = rand(rng, 1, 1, 3, 3)
    b = rand(rng, 3)
    y = np.array(pk.pointwise_conv(x, wt, b, act=act))
    assert y.min() >= lo
    if hi is not None:
        assert y.max() <= hi

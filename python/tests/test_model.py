"""L2 correctness: the graph→JAX builder over the Rust-exported container.

The pallas and jnp backends must agree at full-model scale, and every
intermediate shape must match what the Rust graph declares (the builder
asserts this internally).
"""

import os
import subprocess

import numpy as np
import jax.numpy as jnp
import pytest

from compile import graph_ir, model

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
SRC = os.path.join(ROOT, "artifacts", "src")
BIN = os.path.join(ROOT, "target", "release", "mcu-reorder")


def container(name):
    json_path = os.path.join(SRC, f"{name}.json")
    weights_path = os.path.join(SRC, f"{name}.weights.bin")
    if not os.path.exists(json_path):
        if not os.path.exists(BIN):
            pytest.skip("run `make artifacts` first (rust exporter not built)")
        os.makedirs(SRC, exist_ok=True)
        subprocess.run(
            [BIN, "export", "--model", name, "--dtype", "f32",
             "--json", json_path, "--weights", weights_path],
            check=True,
        )
    return graph_ir.load_graph(json_path, weights_path)


def ramp_input(g):
    shape = tuple(g.tensors[g.inputs[0]].shape)
    n = int(np.prod(shape))
    return jnp.asarray(
        [(((i % 17) - 8.0) / 8.0) for i in range(n)], dtype=jnp.float32
    ).reshape(shape)


@pytest.mark.parametrize("name", ["tiny", "mobilenet", "swiftnet"])
def test_backends_agree(name):
    g = container(name)
    x = ramp_input(g)
    out_p = model.build_forward(g, backend="pallas")(x)
    out_j = model.build_forward(g, backend="jnp")(x)
    assert len(out_p) == len(out_j) == len(g.outputs)
    for a, b in zip(out_p, out_j):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("name", ["tiny", "mobilenet", "swiftnet"])
def test_output_is_probability(name):
    g = container(name)
    x = ramp_input(g)
    (probs,) = model.build_forward(g, backend="pallas")(x)
    probs = np.array(probs)
    assert probs.shape == tuple(g.tensors[g.outputs[0]].shape)
    np.testing.assert_allclose(probs.sum(), 1.0, atol=1e-5)
    assert (probs >= 0).all()


def test_wrong_input_shape_rejected():
    g = container("tiny")
    f = model.build_forward(g, backend="jnp")
    with pytest.raises(ValueError):
        f(jnp.zeros((1, 4, 4, 2), jnp.float32))


def test_weightless_graph_requires_no_blob(tmp_path):
    import json as J
    doc = {
        "format": "mcu-reorder/v1",
        "name": "id",
        "tensors": [
            {"id": 0, "name": "x", "shape": [1, 2], "dtype": "f32", "weight": False},
            {"id": 1, "name": "sm", "shape": [1, 2], "dtype": "f32", "weight": False},
        ],
        "ops": [
            {"id": 0, "name": "sm", "kind": "Softmax", "attrs": {},
             "inputs": [0], "weights": [], "output": 1}
        ],
        "inputs": [0],
        "outputs": [1],
    }
    p = tmp_path / "id.json"
    p.write_text(J.dumps(doc))
    g = graph_ir.load_graph(str(p))
    (y,) = model.build_forward(g, backend="jnp")(jnp.asarray([[1.0, 2.0]]))
    np.testing.assert_allclose(np.array(y).sum(), 1.0, atol=1e-6)


def test_missing_weights_detected(tmp_path):
    g = container("tiny")
    g.weight_data = {}
    with pytest.raises(ValueError, match="no weight data"):
        model.build_forward(g)


def test_execution_order_is_respected():
    g = container("tiny")
    x = ramp_input(g)
    base = model.build_forward(g, backend="jnp")(x)
    # Reversed-but-valid order: branch B before branch A (ops 2 and 1 are
    # both enabled after op 0 in tiny-cnn).
    g.execution_order = [0, 2, 1, 3, 4, 5, 6]
    swapped = model.build_forward(g, backend="jnp")(x)
    for a, b in zip(base, swapped):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=0, rtol=0)

#!/usr/bin/env python3
"""Exact-schedule DP mirror of the Rust analytic stack.

An independent Python implementation of the byte-exact working-set
accounting (including the structural in-place rule of streaming concat
elision), Algorithm-1 optimal scheduling, the split-graph rewriter and
the beam split planner — faithful to `rust/src/sched`, `rust/src/split`
and `rust/src/models` down to tie-breaking order.

Purpose:
  * cross-check the Rust scheduler/planner peaks from a second,
    independently-written implementation (the "exact-schedule DP mirror"
    the split acceptance tests refer to);
  * compute the gated `BENCH_baseline/partial_exec.json` values
    analytically (`python3 tools/schedule_mirror/mirror.py --baseline`);
  * compute the gated `BENCH_baseline/scheduler_scaling.json` values for
    the layered synthetic models (`--scaling-baseline`), and check the
    Rust scaling bench against them (`--check BENCH_scheduler_scaling.json`);
  * compute the gated `BENCH_baseline/serving.json` `_floor` counters of
    the plan-serving bench by simulating its deterministic request
    stream against a bit-exact tick-LRU (`--serving-baseline`), and
    check the Rust serving bench against them (`--check BENCH_serving.json`);
  * compute the gated `BENCH_baseline/codegen.json` `_bytes` sizes of the
    AOT codegen bench — the static arena each emitted C artifact declares
    (DP order + best-fit-decreasing placement, both transcribed from
    `rust/src/alloc/planner.rs` down to tie-breaks) and its baked-in
    weight-table rodata (`--codegen-baseline`), and check the Rust
    codegen bench against them (`--check BENCH_codegen.json`). The
    `tflitecnn_i8` arena is deliberately not mirrored: the TFLite
    importer and this mirror assign different tensor ids, which changes
    best-fit placement order (rodata is id-independent and is mirrored).

Everything here is deterministic and analytic — no timing, no RNG beyond
the mirrored xoshiro256** used by the synthetic model generators and the
serving bench's zipf request stream.
"""

import argparse
import json
import sys

MASK = (1 << 64) - 1

# ---------------------------------------------------------------------------
# util::rng — splitmix64-seeded xoshiro256** (bit-exact mirror)
# ---------------------------------------------------------------------------


class Rng:
    def __init__(self, seed):
        s = seed & MASK
        st = []
        for _ in range(4):
            s = (s + 0x9E3779B97F4A7C15) & MASK
            z = s
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            st.append(z ^ (z >> 31))
        self.s = st

    def next_u64(self):
        s = self.s
        r = (self._rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = self._rotl(s[3], 45)
        return r

    @staticmethod
    def _rotl(x, k):
        return ((x << k) | (x >> (64 - k))) & MASK

    def below(self, bound):
        threshold = ((-bound) & MASK) % bound
        while True:
            x = self.next_u64()
            m = x * bound
            if (m & MASK) >= threshold:
                return m >> 64

    def range(self, lo, hi):
        return lo + self.below(hi - lo)


# ---------------------------------------------------------------------------
# graph IR (mirrors rust/src/graph)
# ---------------------------------------------------------------------------

SAME, VALID = "same", "valid"
ROWS, COLS, CHANNELS = "rows", "cols", "channels"
AXES = [ROWS, COLS, CHANNELS]
AXIS_DIM = {ROWS: 1, COLS: 2, CHANNELS: 3}


class Tensor:
    __slots__ = ("id", "name", "shape", "dsize", "is_weight", "producer", "consumers")

    def __init__(self, id, name, shape, dsize, is_weight):
        self.id, self.name, self.shape, self.dsize, self.is_weight = (
            id,
            name,
            shape,
            dsize,
            is_weight,
        )
        self.producer = None
        self.consumers = []

    def elems(self):
        n = 1
        for d in self.shape:
            n *= d
        return n

    def bytes(self):
        return self.elems() * self.dsize


class Op:
    __slots__ = ("id", "name", "kind", "inputs", "weights", "output")

    def __init__(self, id, name, kind, inputs, weights, output):
        self.id, self.name, self.kind = id, name, kind
        self.inputs, self.weights, self.output = inputs, weights, output


class Graph:
    def __init__(self, name):
        self.name = name
        self.tensors = []
        self.ops = []
        self.inputs = []
        self.outputs = []

    def add_tensor(self, name, shape, dsize, is_weight=False):
        t = Tensor(len(self.tensors), name, list(shape), dsize, is_weight)
        self.tensors.append(t)
        return t.id

    def add_op(self, name, kind, inputs, weights, out_shape, dsize):
        opid = len(self.ops)
        out = self.add_tensor(name, out_shape, dsize)
        self.tensors[out].producer = opid
        for t in list(inputs) + list(weights):
            self.tensors[t].consumers.append(opid)
        self.ops.append(Op(opid, name, kind, list(inputs), list(weights), out))
        return out

    def op_by_name(self, name):
        for o in self.ops:
            if o.name == name:
                return o
        return None

    def tensor_by_name(self, name):
        for t in self.tensors:
            if t.name == name:
                return t
        return None

    def default_order(self):
        return list(range(len(self.ops)))

    def total_macs(self):
        return sum(op_macs(self, o) for o in self.ops)


def conv_out_dim(inp, k, stride, padding):
    if padding == SAME:
        return -(-inp // stride)
    assert inp >= k
    return (inp - k) // stride + 1


def pad_amounts(inp, k, stride, padding, out):
    if padding == VALID:
        return 0
    total = max((out - 1) * stride + k - inp, 0)
    return total // 2


# builder layers (weights mirror GraphBuilder's creation order; bias dtype
# follows pick_bias: f32 activations -> f32 bias (4B), else i32 (4B))


def conv2d(g, name, x, cout, kernel, stride, padding, dsize):
    n, h, w, cin = g.tensors[x].shape
    oh = conv_out_dim(h, kernel[0], stride[0], padding)
    ow = conv_out_dim(w, kernel[1], stride[1], padding)
    g.add_tensor(name + ".w", [kernel[0], kernel[1], cin, cout], dsize, True)
    g.add_tensor(name + ".b", [cout], 4, True)
    wt, bias = len(g.tensors) - 2, len(g.tensors) - 1
    kind = {"k": "Conv2D", "kernel": kernel, "stride": stride, "padding": padding}
    return g.add_op(name, kind, [x], [wt, bias], [n, oh, ow, cout], dsize)


def dwconv2d(g, name, x, kernel, stride, padding, dsize):
    n, h, w, c = g.tensors[x].shape
    oh = conv_out_dim(h, kernel[0], stride[0], padding)
    ow = conv_out_dim(w, kernel[1], stride[1], padding)
    g.add_tensor(name + ".w", [kernel[0], kernel[1], c], dsize, True)
    g.add_tensor(name + ".b", [c], 4, True)
    wt, bias = len(g.tensors) - 2, len(g.tensors) - 1
    kind = {"k": "DepthwiseConv2D", "kernel": kernel, "stride": stride, "padding": padding}
    return g.add_op(name, kind, [x], [wt, bias], [n, oh, ow, c], dsize)


def dense(g, name, x, out_features, dsize):
    in_features = g.tensors[x].elems()
    g.add_tensor(name + ".w", [in_features, out_features], dsize, True)
    g.add_tensor(name + ".b", [out_features], 4, True)
    wt, bias = len(g.tensors) - 2, len(g.tensors) - 1
    return g.add_op(name, {"k": "Dense"}, [x], [wt, bias], [1, out_features], dsize)


def add_(g, name, a, b):
    return g.add_op(name, {"k": "Add"}, [a, b], [], g.tensors[a].shape, g.tensors[a].dsize)


def concat(g, name, parts):
    shape = list(g.tensors[parts[0]].shape)
    shape[-1] = sum(g.tensors[p].shape[-1] for p in parts)
    return g.add_op(name, {"k": "Concat"}, parts, [], shape, g.tensors[parts[0]].dsize)


def relu(g, name, x, kind="Relu"):
    return g.add_op(name, {"k": kind}, [x], [], g.tensors[x].shape, g.tensors[x].dsize)


def maxpool(g, name, x, kernel, stride, padding):
    n, h, w, c = g.tensors[x].shape
    oh = conv_out_dim(h, kernel[0], stride[0], padding)
    ow = conv_out_dim(w, kernel[1], stride[1], padding)
    kind = {"k": "MaxPool2D", "kernel": kernel, "stride": stride, "padding": padding}
    return g.add_op(name, kind, [x], [], [n, oh, ow, c], g.tensors[x].dsize)


def global_avgpool(g, name, x):
    n, _, _, c = g.tensors[x].shape
    return g.add_op(name, {"k": "GlobalAvgPool"}, [x], [], [n, 1, 1, c], g.tensors[x].dsize)


def softmax(g, name, x):
    return g.add_op(name, {"k": "Softmax"}, [x], [], g.tensors[x].shape, g.tensors[x].dsize)


def reshape(g, name, x, shape):
    return g.add_op(name, {"k": "Reshape"}, [x], [], list(shape), g.tensors[x].dsize)


def synthetic(g, name, inputs, out_bytes, macs):
    return g.add_op(name, {"k": "Synthetic", "macs": macs}, inputs, [], [out_bytes], 1)


# ---------------------------------------------------------------------------
# model zoo (mirrors rust/src/models)
# ---------------------------------------------------------------------------


def figure1():
    g = Graph("figure1")
    t0 = g.add_tensor("t0", [1568], 1)
    g.inputs.append(t0)
    t1 = synthetic(g, "op1", [t0], 3136, 0)
    t2 = synthetic(g, "op2", [t1], 1568, 0)
    t3 = synthetic(g, "op3", [t2], 512, 0)
    t4 = synthetic(g, "op4", [t1], 512, 0)
    t5 = synthetic(g, "op5", [t3], 256, 0)
    t6 = synthetic(g, "op6", [t4], 256, 0)
    t7 = synthetic(g, "op7", [t5, t6], 512, 0)
    g.outputs.append(t7)
    return g


def mobilenet(dsize=1):
    g = Graph("mobilenet")
    x = g.add_tensor("input", [1, 96, 96, 1], dsize)
    g.inputs.append(x)
    t = conv2d(g, "conv1", x, 8, (3, 3), (2, 2), SAME, dsize)
    blocks = [(1, 16), (2, 32), (1, 32), (2, 64), (1, 64), (2, 128), (1, 128), (1, 128),
              (1, 128), (1, 128), (1, 128), (2, 256), (1, 256)]
    for i, (s, cout) in enumerate(blocks):
        n = i + 1
        t = dwconv2d(g, f"dw{n}", t, (3, 3), (s, s), SAME, dsize)
        t = conv2d(g, f"pw{n}", t, cout, (1, 1), (1, 1), SAME, dsize)
    gap = global_avgpool(g, "gap", t)
    fc = dense(g, "fc", gap, 2, dsize)
    sm = softmax(g, "softmax", fc)
    g.outputs.append(sm)
    return g


def _swift_cell(g, name, x, ca_mid, ca_out, cb_out, dsize):
    a1 = conv2d(g, f"{name}.a1", x, ca_mid, (1, 1), (1, 1), SAME, dsize)
    a2 = dwconv2d(g, f"{name}.a2", a1, (3, 3), (1, 1), SAME, dsize)
    a3 = conv2d(g, f"{name}.a3", a2, ca_out, (1, 1), (1, 1), SAME, dsize)
    b1 = dwconv2d(g, f"{name}.b1", x, (3, 3), (1, 1), SAME, dsize)
    b2 = conv2d(g, f"{name}.b2", b1, cb_out, (1, 1), (1, 1), SAME, dsize)
    return concat(g, f"{name}.cat", [a3, b2])


def _swift_transition(g, name, x, cout, dsize):
    d = dwconv2d(g, f"{name}.dw", x, (3, 3), (2, 2), SAME, dsize)
    return conv2d(g, f"{name}.pw", d, cout, (1, 1), (1, 1), SAME, dsize)


def swiftnet(dsize=1):
    g = Graph("swiftnet")
    x = g.add_tensor("input", [1, 96, 96, 3], dsize)
    g.inputs.append(x)
    stem = conv2d(g, "stem", x, 32, (3, 3), (2, 2), SAME, dsize)
    c1 = _swift_cell(g, "c1", stem, 60, 40, 12, dsize)
    t1 = _swift_transition(g, "t1", c1, 64, dsize)
    c2 = _swift_cell(g, "c2", t1, 96, 64, 32, dsize)
    c3 = _swift_cell(g, "c3", c2, 96, 64, 32, dsize)
    t2 = _swift_transition(g, "t2", c3, 128, dsize)
    c4 = _swift_cell(g, "c4", t2, 96, 96, 32, dsize)
    c5 = _swift_cell(g, "c5", c4, 96, 96, 32, dsize)
    c6 = _swift_cell(g, "c6", c5, 96, 96, 32, dsize)
    t3 = _swift_transition(g, "t3", c6, 192, dsize)
    c7 = _swift_cell(g, "c7", t3, 160, 128, 64, dsize)
    p1 = conv2d(g, "tail1", c7, 160, (1, 1), (1, 1), SAME, dsize)
    gap = global_avgpool(g, "gap", p1)
    fc = dense(g, "fc", gap, 2, dsize)
    sm = softmax(g, "softmax", fc)
    g.outputs.append(sm)
    return g


def resnet(dsize=1):
    g = Graph("resnet")
    x = g.add_tensor("input", [1, 32, 32, 3], dsize)
    g.inputs.append(x)
    t = conv2d(g, "stem", x, 16, (3, 3), (1, 1), SAME, dsize)
    for stage, (c, stride) in enumerate([(16, 1), (32, 2), (64, 2)]):
        if stride > 1 or c != 16:
            t = conv2d(g, f"s{stage}.down", t, c, (1, 1), (stride, stride), SAME, dsize)
        for blk in range(2):
            name = f"s{stage}.b{blk}"
            c1 = conv2d(g, f"{name}.c1", t, c // 2, (3, 3), (1, 1), SAME, dsize)
            c2 = conv2d(g, f"{name}.c2", c1, c, (3, 3), (1, 1), SAME, dsize)
            t = add_(g, f"{name}.add", c2, t)
    gap = global_avgpool(g, "gap", t)
    fc = dense(g, "fc", gap, 10, dsize)
    sm = softmax(g, "softmax", fc)
    g.outputs.append(sm)
    return g


def audionet(dsize=1):
    g = Graph("audionet")
    x = g.add_tensor("input", [1, 64, 16, 4], dsize)
    g.inputs.append(x)
    c1 = conv2d(g, "c1", x, 32, (8, 3), (1, 1), SAME, dsize)
    d1 = dwconv2d(g, "d1", c1, (12, 3), (2, 2), SAME, dsize)
    m1 = maxpool(g, "m1", d1, (2, 2), (2, 2), VALID)
    p1 = conv2d(g, "p1", m1, 32, (1, 1), (1, 1), SAME, dsize)
    d2 = dwconv2d(g, "d2", p1, (3, 3), (1, 1), SAME, dsize)
    p2 = conv2d(g, "p2", d2, 32, (1, 1), (1, 1), SAME, dsize)
    gap = global_avgpool(g, "gap", p2)
    fc = dense(g, "fc", gap, 4, dsize)
    sm = softmax(g, "softmax", fc)
    g.outputs.append(sm)
    return g


def streamnet(dsize=1):
    g = Graph("streamnet")
    x = g.add_tensor("input", [1, 32, 32, 2], dsize)
    g.inputs.append(x)
    c1 = conv2d(g, "c1", x, 32, (3, 3), (1, 1), SAME, dsize)
    d1 = dwconv2d(g, "d1", c1, (3, 3), (1, 1), SAME, dsize)
    gap = global_avgpool(g, "gap", d1)
    fc = dense(g, "fc", gap, 4, dsize)
    sm = softmax(g, "softmax", fc)
    g.outputs.append(sm)
    return g


def tiny(dsize=1):
    g = Graph("tiny")
    x = g.add_tensor("x", [1, 8, 8, 2], dsize)
    g.inputs.append(x)
    c1 = conv2d(g, "c1", x, 4, (3, 3), (1, 1), SAME, dsize)
    dw = dwconv2d(g, "dw", c1, (3, 3), (2, 2), SAME, dsize)
    pw = conv2d(g, "pw", c1, 4, (1, 1), (2, 2), SAME, dsize)
    cat = concat(g, "cat", [dw, pw])
    gap = global_avgpool(g, "gap", cat)
    fc = dense(g, "fc", gap, 3, dsize)
    sm = softmax(g, "softmax", fc)
    g.outputs.append(sm)
    return g


def tflitecnn(dsize=1):
    """The de-fused import of tools/tflite_fixtures cnn_int8.tflite.

    Mirrors what rust/src/tflite/import.rs produces for the fixture: the
    TFLite operator list with every fused activation materialized as an
    explicit Relu/Relu6 op (the importer's de-fusing contract), executed
    in flatbuffer operator order.
    """
    g = Graph("tflitecnn")
    x = g.add_tensor("input", [1, 16, 16, 2], dsize)
    g.inputs.append(x)
    c1p = conv2d(g, "conv1.preact", x, 8, (3, 3), (1, 1), SAME, dsize)
    c1 = relu(g, "conv1", c1p, "Relu6")
    dwp = dwconv2d(g, "dw1.preact", c1, (3, 3), (2, 2), SAME, dsize)
    dw = relu(g, "dw1", dwp, "Relu6")
    pwp = conv2d(g, "pwa.preact", dw, 8, (1, 1), (1, 1), SAME, dsize)
    pw = relu(g, "pwa", pwp)
    a = add_(g, "add1", dw, pw)
    c = concat(g, "cat", [a, pw])
    p = maxpool(g, "pool", c, (2, 2), (2, 2), VALID)
    m = global_avgpool(g, "mean", p)
    r = reshape(g, "reshape", m, [1, 16])
    f = dense(g, "fc", r, 4, dsize)
    s = softmax(g, "softmax", f)
    g.outputs.append(s)
    return g


def series_parallel(rng, depth, width):
    g = Graph("series-parallel")
    cur = g.add_tensor("x", [256 + 64 * rng.range(0, 8)], 1)
    g.inputs.append(cur)
    for d in range(depth):
        joins = []
        for w in range(width):
            t = cur
            hops = 1 + rng.range(0, 3)
            for h in range(hops):
                nbytes = 64 * (1 + rng.range(0, 32))
                t = synthetic(g, f"d{d}b{w}h{h}", [t], nbytes, 500)
            joins.append(t)
        if len(joins) == 1:
            cur = joins[0]
        else:
            nbytes = 64 * (1 + rng.range(0, 16))
            cur = synthetic(g, f"d{d}join", joins, nbytes, 500)
    g.outputs.append(cur)
    return g


def layered(rng, n_ops):
    """Bit-exact twin of `rust/src/models/synth.rs::layered`.

    Exactly `n_ops` operators: an MBConv-style expand/depthwise/contract
    stem (x4 channel expansion — the fat, splittable intermediates the
    planner runs of the scaling bench bank on) followed by a random walk
    over realistic block types (conv / dw+pw pair / relu / residual pair
    / stride-2 downsample) on a 32x32x8 input, closed by gap ->
    dense(10) -> softmax. Consumes one `rng.range(0, 8)` per loop
    iteration, so the Rust generator and this one stay on the same
    xoshiro stream call for call — any change here must be made in
    lockstep with the Rust side.
    """
    assert n_ops >= 7, "layered graphs need the 3-op stem, a body and the 3-op tail"
    g = Graph("layered")
    cur = g.add_tensor("x", [1, 32, 32, 8], 1)
    g.inputs.append(cur)
    h = 32
    c = 8
    cur = conv2d(g, "stem.ex", cur, 4 * c, (1, 1), (1, 1), SAME, 1)
    cur = dwconv2d(g, "stem.dw", cur, (3, 3), (1, 1), SAME, 1)
    cur = conv2d(g, "stem.pw", cur, c, (1, 1), (1, 1), SAME, 1)
    body = n_ops - 6
    emitted = 0
    i = 0
    while emitted < body:
        left = body - emitted
        r = rng.range(0, 8)
        if r <= 2 or left == 1:
            cur = conv2d(g, f"l{i}.conv", cur, c, (3, 3), (1, 1), SAME, 1)
            emitted += 1
        elif r <= 4 and left >= 2:
            cur = dwconv2d(g, f"l{i}.dw", cur, (3, 3), (1, 1), SAME, 1)
            cur = conv2d(g, f"l{i}.pw", cur, c, (1, 1), (1, 1), SAME, 1)
            emitted += 2
        elif r == 5:
            cur = relu(g, f"l{i}.relu", cur)
            emitted += 1
        elif r == 6 and left >= 3 and h <= 8:
            a = conv2d(g, f"l{i}.ra", cur, c, (3, 3), (1, 1), SAME, 1)
            z = conv2d(g, f"l{i}.rb", a, c, (3, 3), (1, 1), SAME, 1)
            cur = add_(g, f"l{i}.add", cur, z)
            emitted += 3
        elif h > 4:
            h = -(-h // 2)
            c = min(c * 2, 64)
            cur = conv2d(g, f"l{i}.down", cur, c, (3, 3), (2, 2), SAME, 1)
            emitted += 1
        else:
            cur = conv2d(g, f"l{i}.conv", cur, c, (3, 3), (1, 1), SAME, 1)
            emitted += 1
        i += 1
    gap = global_avgpool(g, "gap", cur)
    fc = dense(g, "fc", gap, 10, 1)
    sm = softmax(g, "softmax", fc)
    g.outputs.append(sm)
    return g


# ---------------------------------------------------------------------------
# MACs (mirrors graph::Op::macs, incl. Partial / PartialInto band scaling)
# ---------------------------------------------------------------------------


def axis_extent(shape, axis):
    return shape[AXIS_DIM[axis]] if len(shape) == 4 else shape[-1]


def _inner_macs(g, op, inner, band_out_elems):
    k = inner["k"]
    if k == "Conv2D":
        cin = g.tensors[op.inputs[0]].shape[-1]
        return band_out_elems * inner["kernel"][0] * inner["kernel"][1] * cin
    if k == "DepthwiseConv2D":
        return band_out_elems * inner["kernel"][0] * inner["kernel"][1]
    if k == "Dense":
        return band_out_elems * g.tensors[op.inputs[0]].elems()
    if k in ("MaxPool2D", "AvgPool2D"):
        return band_out_elems * inner["kernel"][0] * inner["kernel"][1]
    if k == "BatchNorm":
        return 2 * band_out_elems
    return band_out_elems


def op_macs(g, op):
    out = g.tensors[op.output]
    oe = out.elems()
    k = op.kind["k"]
    if k == "Conv2D":
        return oe * op.kind["kernel"][0] * op.kind["kernel"][1] * g.tensors[op.inputs[0]].shape[-1]
    if k == "DepthwiseConv2D":
        return oe * op.kind["kernel"][0] * op.kind["kernel"][1]
    if k == "Dense":
        return oe * g.tensors[op.inputs[0]].elems()
    if k in ("Add", "Relu", "Relu6", "Softmax"):
        return oe
    if k == "BatchNorm":
        return 2 * oe
    if k in ("MaxPool2D", "AvgPool2D"):
        return oe * op.kind["kernel"][0] * op.kind["kernel"][1]
    if k == "GlobalAvgPool":
        return g.tensors[op.inputs[0]].elems()
    if k in ("Concat", "Reshape", "ConcatSlices"):
        return 0
    if k == "Synthetic":
        return op.kind["macs"]
    if k == "Partial":
        return _inner_macs(g, op, op.kind["inner"], oe)
    if k == "PartialInto":
        band = oe // max(axis_extent(out.shape, op.kind["axis"]), 1) * op.kind["len"]
        return _inner_macs(g, op, op.kind["inner"], band)
    raise ValueError(k)


# ---------------------------------------------------------------------------
# sched (mirrors rust/src/sched: accumulators, simulate, Algorithm-1 DP)
# ---------------------------------------------------------------------------


def activation_consumers(g, t):
    return sum(1 for c in g.tensors[t].consumers if t in g.ops[c].inputs)


def elided_accumulators(g):
    acc = []
    for op in g.ops:
        a = None
        if op.kind["k"] == "PartialInto" and len(op.inputs) > 1:
            cand = op.inputs[1]
            tens = g.tensors[cand]
            if (
                activation_consumers(g, cand) == 1
                and cand not in g.outputs
                and tens.bytes() == g.tensors[op.output].bytes()
            ):
                a = cand
        acc.append(a)
    return acc


def simulate(g, order):
    acc = elided_accumulators(g)
    n = len(g.tensors)
    remaining = [0] * n
    for op in g.ops:
        for t in op.inputs:
            remaining[t] += 1
    is_output = [False] * n
    for t in g.outputs:
        is_output[t] = True
    resident = [False] * n
    for t in g.inputs:
        resident[t] = True
    steps = []
    peak, peak_step = 0, 0
    for i, opid in enumerate(order):
        op = g.ops[opid]
        resident[op.output] = True
        live = [t for t in range(n) if resident[t]]
        nbytes = sum(g.tensors[t].bytes() for t in live)
        if acc[opid] is not None:
            nbytes -= g.tensors[op.output].bytes()
        if nbytes > peak:
            peak, peak_step = nbytes, i
        steps.append((opid, live, nbytes))
        for t in op.inputs:
            remaining[t] -= 1
            if remaining[t] == 0 and not is_output[t]:
                resident[t] = False
        if remaining[op.output] == 0 and not is_output[op.output]:
            resident[op.output] = False
    return steps, peak, peak_step


def tensor_ancestors(g):
    n = len(g.tensors)
    anc = [0] * n
    for op in g.ops:  # op ids are topological for builder/rewriter graphs
        a = 0
        for i in op.inputs:
            a |= (1 << i) | anc[i]
        anc[op.output] = a
    return anc


class Dp:
    """Algorithm 1 over tensor-set states (bitmask ints)."""

    def __init__(self, g):
        n = len(g.tensors)
        self.g = g
        self.bytes = [t.bytes() for t in g.tensors]
        self.has_producer = [t.producer is not None for t in g.tensors]
        self.producer_inputs = [[] for _ in range(n)]
        for op in g.ops:
            self.producer_inputs[op.output] = op.inputs
        self.inplace = [False] * n
        for op, a in zip(g.ops, elided_accumulators(g)):
            if a is not None:
                self.inplace[op.output] = True
        self.anc = tensor_ancestors(g)
        self.memo = {}

    def sum_bytes(self, x):
        s = 0
        while x:
            t = (x & -x).bit_length() - 1
            s += self.bytes[t]
            x &= x - 1
        return s

    def mem(self, x):
        hit = self.memo.get(x)
        if hit is not None:
            return hit
        stack = [(x, None)]
        # Iterative post-order to dodge Python's recursion limit.
        while stack:
            state, _ = stack[-1]
            if state in self.memo:
                stack.pop()
                continue
            bits = []
            s = state
            while s:
                t = (s & -s).bit_length() - 1
                bits.append(t)
                s &= s - 1
            prods = [t for t in bits if self.has_producer[t]]
            if not prods:
                self.memo[state] = (self.sum_bytes(state), None)
                stack.pop()
                continue
            pending = []
            nexts = {}
            for xt in prods:
                if any(r != xt and (self.anc[r] >> xt) & 1 for r in bits):
                    continue
                nxt = state & ~(1 << xt)
                for i in self.producer_inputs[xt]:
                    nxt |= 1 << i
                nexts[xt] = nxt
                if nxt not in self.memo:
                    pending.append(nxt)
            if pending:
                for p in pending:
                    stack.append((p, None))
                continue
            best, choice = None, None
            for xt in prods:
                if xt not in nexts:
                    continue
                nxt = nexts[xt]
                x_bytes = 0 if self.inplace[xt] else self.bytes[xt]
                step = self.sum_bytes(nxt) + x_bytes
                if (nxt >> xt) & 1:
                    step -= x_bytes
                rec = self.memo[nxt][0]
                m = max(rec, step)
                if best is None or m < best:
                    best, choice = m, xt
            self.memo[state] = (best, choice)
            stack.pop()
        return self.memo[x]

    def reconstruct(self, start):
        order_rev = []
        state = start
        while True:
            _, choice = self.memo[state]
            if choice is None:
                break
            order_rev.append(self.g.tensors[choice].producer)
            nxt = state & ~(1 << choice)
            for i in self.producer_inputs[choice]:
                nxt |= 1 << i
            state = nxt
        order_rev.reverse()
        return order_rev


def optimal(g):
    dp = Dp(g)
    start = 0
    for t in g.outputs:
        start |= 1 << t
    peak, _ = dp.mem(start)
    order = dp.reconstruct(start)
    return order, peak


# ---------------------------------------------------------------------------
# static arena planner (mirrors rust/src/alloc/planner.rs)
# ---------------------------------------------------------------------------


def storage_roots(g):
    """Storage-sharing root per tensor: a join-elided accumulator chain
    (`PartialInto` writing through its accumulator) is one buffer, so
    every member resolves to the chain's root tensor."""
    root = list(range(len(g.tensors)))
    for op, a in zip(g.ops, elided_accumulators(g)):
        if a is not None:
            r = a
            while root[r] != r:
                r = root[r]
            root[op.output] = r
    return root


def plan_lifetimes(g, order):
    """Activation lifetimes under `order` (weights excluded), as
    `[tensor, start, end, bytes]` rows in tensor-id order — transcribed
    from `alloc::plan_lifetimes`: producers set the start (graph inputs
    start at 0), outputs live to the final step, consumers extend the
    end only when the tensor is a data input (not a weight operand)."""
    n_steps = len(order)
    step_of = {o: i for i, o in enumerate(order)}
    out = []
    for t in g.tensors:
        if t.is_weight:
            continue
        start = step_of[t.producer] if t.producer is not None else 0
        end = n_steps - 1 if t.id in g.outputs else start
        for c in t.consumers:
            if t.id in g.ops[c].inputs:
                end = max(end, step_of[c])
        out.append([t.id, start, end, t.bytes()])
    return out


def best_fit(g, order):
    """Arena size of the lifetime-aware best-fit-decreasing placement,
    transcribed from `StaticPlan::best_fit`: sharing groups merged into
    one slot (union lifetime, max size), groups placed largest-first
    (ties by tensor id), each at the lowest offset whose address range
    is free across its whole lifetime."""
    root = storage_roots(g)
    merged = {}
    for tid, start, end, nbytes in plan_lifetimes(g, order):
        r = root[tid]
        m = merged.get(r)
        if m is None:
            merged[r] = [r, start, end, nbytes]
        else:
            m[1] = min(m[1], start)
            m[2] = max(m[2], end)
            m[3] = max(m[3], nbytes)
    groups = sorted(merged.values(), key=lambda m: (-m[3], m[0]))
    placed = []  # (offset, [tensor, start, end, bytes])
    arena = 0
    for grp in groups:
        _, start, end, nbytes = grp
        busy = sorted(
            (off, off + o[3])
            for off, o in placed
            if not (o[2] < start or o[1] > end)
        )
        offset = 0
        for lo, hi in busy:
            if lo >= offset + nbytes:
                break
            offset = max(offset, hi)
        arena = max(arena, offset + nbytes)
        placed.append((offset, grp))
    return arena


# ---------------------------------------------------------------------------
# split (mirrors rust/src/split: geometry, rewrite, beam search)
# ---------------------------------------------------------------------------

WINDOWED_KINDS = ("Conv2D", "DepthwiseConv2D", "MaxPool2D", "AvgPool2D")
POINTWISE_KINDS = ("Relu", "Relu6", "BatchNorm")


def nhwc1(shape):
    return len(shape) == 4 and shape[0] == 1


def slice_geom(g, op, axis):
    if len(op.inputs) != 1:
        return None
    ish = g.tensors[op.inputs[0]].shape
    osh = g.tensors[op.output].shape
    if not nhwc1(ish) or not nhwc1(osh):
        return None
    k = op.kind["k"]
    if axis == CHANNELS:
        if k == "Conv2D":
            return ("chanproject",)
        if k in ("DepthwiseConv2D", "MaxPool2D", "AvgPool2D", "Relu", "Relu6", "BatchNorm"):
            return ("chanparallel",)
        return None
    d = AXIS_DIM[axis]
    pick = 0 if axis == ROWS else 1
    if k in WINDOWED_KINDS:
        kk = op.kind["kernel"][pick]
        ss = op.kind["stride"][pick]
        pad = pad_amounts(ish[d], kk, ss, op.kind["padding"], osh[d])
        return ("windowed", kk, ss, pad)
    if k in POINTWISE_KINDS:
        return ("pointwise",)
    return None


def in_band(geom, n_in, band):
    if geom[0] != "windowed":
        return band
    _, k, stride, pad = geom
    lo_raw = band[0] * stride - pad
    hi_raw = (band[1] - 1) * stride + k - pad
    lo = min(max(lo_raw, 0), n_in)
    hi = min(max(hi_raw, 0), n_in)
    return (lo, hi)


def partition(n, k):
    base, rem = n // k, n % k
    out, start = [], 0
    for j in range(k):
        rows = base + (1 if j < rem else 0)
        out.append((start, start + rows))
        start += rows
    return out


def pad_eff(geom, out_start, in_start):
    if geom[0] != "windowed":
        return 0
    _, _, stride, pad = geom
    return pad + in_start - out_start * stride


class SplitError(Exception):
    pass


def apply_segment(g, ops, factor, axis, elide):
    m, k = len(ops), factor
    if m == 0 or k < 2:
        raise SplitError("bad segment")
    for o in ops:
        if o >= len(g.ops):
            raise SplitError("range")
        if g.ops[o].kind["k"] in ("Partial", "ConcatSlices", "PartialInto"):
            raise SplitError("artifact")
    head = g.ops[ops[0]]
    if len(head.inputs) != 1:
        raise SplitError("head inputs")
    for a, b in zip(ops, ops[1:]):
        out = g.ops[a].output
        nxt = g.ops[b]
        if len(nxt.inputs) != 1 or nxt.inputs[0] != out:
            raise SplitError("not chained")
        if activation_consumers(g, out) != 1 or out in g.outputs:
            raise SplitError("interior consumers")
    if head.kind["k"] == "Dense":
        if m != 1:
            raise SplitError("dense multi")
        return _apply_dense(g, ops[0], k, elide)
    return _apply_chain(g, ops, factor, axis, elide)


def _apply_chain(g, ops, k, axis, elide):
    m = len(ops)
    geoms = []
    for i, oid in enumerate(ops):
        geom = slice_geom(g, g.ops[oid], axis)
        if geom is None:
            raise SplitError("not sliceable")
        if geom[0] in ("pointwise", "chanparallel") and i == 0:
            raise SplitError("head must anchor")
        if geom[0] == "chanproject" and i > 0:
            raise SplitError("conv inside channel chain")
        geoms.append(geom)
    d = AXIS_DIM[axis]
    dim_in = [g.tensors[g.ops[o].inputs[0]].shape[d] for o in ops]
    last_old = ops[-1]
    n_out_last = g.tensors[g.ops[last_old].output].shape[d]
    if k > n_out_last:
        raise SplitError("factor too big")
    bands = []
    for part in partition(n_out_last, k):
        row = [part] * m
        for i in range(m - 1, 0, -1):
            row[i - 1] = in_band(geoms[i], dim_in[i], row[i])
            if row[i - 1][1] - row[i - 1][0] == 0:
                raise SplitError("pad-only band")
        bands.append(row)

    dropped = set(g.ops[o].output for o in ops[:-1])
    in_seg = set(ops)
    first = ops[0]

    ng = Graph(g.name)
    tmap = {}
    for t in g.tensors:
        if t.id in dropped:
            continue
        tmap[t.id] = ng.add_tensor(t.name, t.shape, t.dsize, t.is_weight)
    join_old = g.ops[last_old].output

    def emit(name, kind, inputs, weights, output):
        opid = len(ng.ops)
        ng.tensors[output].producer = opid
        for t in inputs + weights:
            ng.tensors[t].consumers.append(opid)
        ng.ops.append(Op(opid, name, kind, inputs, weights, output))

    for op in g.ops:
        if op.id in in_seg:
            if op.id != first:
                continue
            chain_in = tmap[g.ops[first].inputs[0]]
            join_out = tmap[join_old]
            join_shape = list(g.tensors[join_old].shape)
            join_ds = g.tensors[join_old].dsize
            slabs = []
            acc = None
            for j, band_row in enumerate(bands):
                cur = chain_in
                cur_start = 0
                for i, oid in enumerate(ops):
                    o = g.ops[oid]
                    band = band_row[i]
                    pad = pad_eff(geoms[i], band[0], cur_start)
                    name = f"{o.name}#s{j}"
                    weights = [tmap[t] for t in o.weights]
                    if elide and i == m - 1:
                        if j == k - 1:
                            out = join_out
                        else:
                            out = ng.add_tensor(f"{o.name}#w{j}", join_shape, join_ds)
                        kind = {
                            "k": "PartialInto",
                            "inner": o.kind,
                            "axis": axis,
                            "pad": pad,
                            "offset": band[0],
                            "len": band[1] - band[0],
                        }
                        inputs = [cur] + ([acc] if acc is not None else [])
                        emit(name, kind, inputs, weights, out)
                        acc = out
                    else:
                        shape = list(g.tensors[o.output].shape)
                        shape[d] = band[1] - band[0]
                        slab = ng.add_tensor(name, shape, g.tensors[o.output].dsize)
                        kind = {
                            "k": "Partial",
                            "inner": o.kind,
                            "axis": axis,
                            "pad": pad,
                            "offset": band[0],
                        }
                        emit(name, kind, [cur], weights, slab)
                        cur = slab
                    cur_start = band[0]
                if not elide:
                    slabs.append(cur)
            if not elide:
                emit(f"{g.ops[last_old].name}#cat", {"k": "ConcatSlices", "axis": axis},
                     slabs, [], join_out)
            continue
        emit(op.name, op.kind, [tmap[t] for t in op.inputs],
             [tmap[t] for t in op.weights], tmap[op.output])
    ng.inputs = [tmap[t] for t in g.inputs]
    ng.outputs = [tmap[t] for t in g.outputs]
    return ng


def _apply_dense(g, oid, k, elide):
    op = g.ops[oid]
    out_t = g.tensors[op.output]
    if len(out_t.shape) != 2 or out_t.shape[0] != 1:
        raise SplitError("dense shape")
    n = out_t.shape[1]
    if k > n:
        raise SplitError("factor too big")
    ng = Graph(g.name)
    tmap = {}
    for t in g.tensors:
        tmap[t.id] = ng.add_tensor(t.name, t.shape, t.dsize, t.is_weight)

    def emit(name, kind, inputs, weights, output):
        opid2 = len(ng.ops)
        ng.tensors[output].producer = opid2
        for t in inputs + weights:
            ng.tensors[t].consumers.append(opid2)
        ng.ops.append(Op(opid2, name, kind, inputs, weights, output))

    for o in g.ops:
        if o.id != oid:
            emit(o.name, o.kind, [tmap[t] for t in o.inputs],
                 [tmap[t] for t in o.weights], tmap[o.output])
            continue
        cur = tmap[op.inputs[0]]
        join_out = tmap[op.output]
        slabs = []
        acc = None
        for j, band in enumerate(partition(n, k)):
            name = f"{op.name}#s{j}"
            weights = [tmap[t] for t in op.weights]
            if elide:
                if j == k - 1:
                    out = join_out
                else:
                    out = ng.add_tensor(f"{op.name}#w{j}", [1, n], out_t.dsize)
                kind = {"k": "PartialInto", "inner": op.kind, "axis": CHANNELS,
                        "pad": 0, "offset": band[0], "len": band[1] - band[0]}
                inputs = [cur] + ([acc] if acc is not None else [])
                emit(name, kind, inputs, weights, out)
                acc = out
            else:
                slab = ng.add_tensor(name, [1, band[1] - band[0]], out_t.dsize)
                kind = {"k": "Partial", "inner": op.kind, "axis": CHANNELS,
                        "pad": 0, "offset": band[0]}
                emit(name, kind, [cur], weights, slab)
                slabs.append(slab)
        if not elide:
            emit(f"{op.name}#cat", {"k": "ConcatSlices", "axis": CHANNELS},
                 slabs, [], join_out)
    ng.inputs = [tmap[t] for t in g.inputs]
    ng.outputs = [tmap[t] for t in g.outputs]
    return ng


def interior_sliceable(g, o, axis):
    geom = slice_geom(g, g.ops[o], axis)
    return geom is not None and geom[0] in ("windowed", "pointwise", "chanparallel")


def head_sliceable(g, o, axis):
    geom = slice_geom(g, g.ops[o], axis)
    return geom is not None and geom[0] in ("windowed", "chanproject")


def sole_consumer(g, t):
    if t in g.outputs:
        return None
    cons = [c for c in g.tensors[t].consumers if t in g.ops[c].inputs]
    if len(cons) != 1:
        return None
    return cons[0]


def chain_through(g, anchor, axis):
    if not interior_sliceable(g, anchor, axis) and not head_sliceable(g, anchor, axis):
        return []
    chain = [anchor]
    while True:
        head = chain[0]
        if not interior_sliceable(g, head, axis):
            break
        inp = g.ops[head].inputs[0]
        prev = g.tensors[inp].producer
        if prev is None:
            break
        if sole_consumer(g, g.ops[prev].output) != head:
            break
        if interior_sliceable(g, prev, axis) or head_sliceable(g, prev, axis):
            chain.insert(0, prev)
        else:
            break
    while True:
        tail = chain[-1]
        nxt = sole_consumer(g, g.ops[tail].output)
        if nxt is None or not interior_sliceable(g, nxt, axis):
            break
        chain.append(nxt)
    return chain


def segments_around(g, anchor, axis, max_segment):
    chain = chain_through(g, anchor, axis)
    if anchor not in chain:
        return []
    pos = chain.index(anchor)
    segs = []
    for s in range(pos + 1):
        if not head_sliceable(g, chain[s], axis):
            continue
        for e in range(pos, len(chain)):
            if e + 1 - s > max_segment:
                break
            segs.append(chain[s:e + 1])
    return segs


def candidate_moves(g, steps, peak_step, opts):
    opid, resident, _ = steps[peak_step]
    anchors = [opid]
    for t in resident:
        p = g.tensors[t].producer
        if p is not None:
            anchors.append(p)
        for c in g.tensors[t].consumers:
            if t in g.ops[c].inputs:
                anchors.append(c)
    anchors = sorted(set(anchors))
    moves = []
    for axis in opts["axes"]:
        n_axis = 0
        done = False
        for a in anchors:
            if done:
                break
            for s in segments_around(g, a, axis, opts["max_segment"]):
                mv = (tuple(s), axis)
                if mv not in moves:
                    moves.append(mv)
                    n_axis += 1
                    if n_axis >= opts["max_candidates"]:
                        done = True
                        break
    for op in g.ops:
        if op.kind["k"] == "Dense":
            out = g.tensors[op.output].shape
            if len(out) == 2 and out[1] >= 2:
                mv = ((op.id,), CHANNELS)
                if mv not in moves:
                    moves.append(mv)
    return moves


DEFAULT_OPTS = {
    "max_factor": 4,
    "max_segment": 4,
    "sram_budget": None,
    "max_rounds": 3,
    "max_candidates": 48,
    "beam_width": 2,
    "axes": [ROWS, COLS, CHANNELS],
    "elide": True,
}

QUICK_OPTS = dict(DEFAULT_OPTS, max_factor=3, max_rounds=1, max_candidates=24, beam_width=1)

# The preset the layered-100 planner run of the scheduler_scaling bench
# uses (mirrors `rust/benches/scheduler_scaling.rs`): small factors and
# rounds so the mirror's naive full-DP scoring stays tractable at 100 ops.
SCALING_OPTS = dict(DEFAULT_OPTS, max_factor=2, max_rounds=2, max_candidates=8, beam_width=2)


def graph_eq(a, b):
    """Structural graph equality (mirrors the Rust `Graph` PartialEq):
    same tensors, ops, inputs and outputs, field for field. The planner's
    frontier dedup keys on this, so it must declare two mirror graphs
    equal exactly when the corresponding Rust graphs are equal."""
    if a is b:
        return True
    if a.name != b.name or a.inputs != b.inputs or a.outputs != b.outputs:
        return False
    if len(a.tensors) != len(b.tensors) or len(a.ops) != len(b.ops):
        return False
    for t, u in zip(a.tensors, b.tensors):
        if (t.name, t.shape, t.dsize, t.is_weight, t.producer, t.consumers) != (
                u.name, u.shape, u.dsize, u.is_weight, u.producer, u.consumers):
            return False
    for o, p in zip(a.ops, b.ops):
        if (o.name, o.kind, o.inputs, o.weights, o.output) != (
                p.name, p.kind, p.inputs, p.weights, p.output):
            return False
    return True


def optimize(g, opts):
    base_order, base_peak = optimal(g)
    beam = [
        {"graph": g, "order": base_order, "peak": base_peak,
         "macs": g.total_macs(), "steps": []}
    ]

    def met(peak):
        return opts["sram_budget"] is not None and peak <= opts["sram_budget"]

    for _ in range(opts["max_rounds"]):
        if met(beam[0]["peak"]):
            break
        # Frontier dedup (mirrors the Rust planner's build_jobs): beam
        # states with structurally identical graphs — the same rewrites
        # reached through different interleavings — enumerate identical
        # moves, so each parent maps to its first identical beam slot and
        # only the first copy of a (parent, segment, factor, axis, elide)
        # candidate is scored.
        canon = []
        for idx, st in enumerate(beam):
            ci = idx
            for j in range(idx):
                if graph_eq(beam[j]["graph"], st["graph"]):
                    ci = j
                    break
            canon.append(ci)
        seen = set()
        pool = list(beam)
        grew = False
        for pi, st in enumerate(beam):
            if met(st["peak"]):
                continue
            steps, _, peak_step = simulate(st["graph"], st["order"])
            variants = []
            for factor in range(2, opts["max_factor"] + 1):
                variants.append((factor, False))
                if opts["elide"]:
                    variants.append((factor, True))
            for seg_ops, axis in candidate_moves(st["graph"], steps, peak_step, opts):
                for factor, elide in variants:
                    key = (canon[pi], seg_ops, factor, axis, elide)
                    if key in seen:
                        continue
                    seen.add(key)
                    try:
                        ng = apply_segment(st["graph"], list(seg_ops), factor, axis, elide)
                    except SplitError:
                        continue
                    order, peak = optimal(ng)
                    if peak >= st["peak"]:
                        continue
                    pool.append({
                        "graph": ng, "order": order, "peak": peak,
                        "macs": ng.total_macs(),
                        "steps": st["steps"] + [
                            ([st["graph"].ops[o].name for o in seg_ops],
                             factor, axis, elide, st["peak"], peak)
                        ],
                    })
                    grew = True
        pool.sort(key=lambda s: (s["peak"], s["macs"]))
        beam = pool[: max(opts["beam_width"], 1)]
        if not grew:
            break
    return beam[0]


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------


def zoo():
    models = [
        ("figure1", figure1()),
        ("mobilenet", mobilenet()),
        ("swiftnet", swiftnet()),
        ("resnet", resnet()),
        ("audionet", audionet()),
        ("streamnet", streamnet()),
        ("tiny", tiny()),
        ("tflitecnn", tflitecnn()),
    ]
    rng = Rng(2025)
    for i in range(2):
        models.append((f"synth-sp{i}", series_parallel(rng, 3, 2)))
    return models


def bench_metrics():
    metrics = {}
    for name, g in zoo():
        _, default_peak = (None, simulate(g, g.default_order())[1])
        rows = optimize(g, dict(DEFAULT_OPTS, axes=[ROWS], elide=False))
        mat = optimize(g, dict(DEFAULT_OPTS, elide=False))
        eli = optimize(g, DEFAULT_OPTS)
        _, reorder_peak = optimal(g)
        metrics[f"{name}.default_peak"] = default_peak
        metrics[f"{name}.reorder_peak"] = reorder_peak
        metrics[f"{name}.rows_only_peak"] = rows["peak"]
        metrics[f"{name}.split_reorder_peak"] = mat["peak"]
        metrics[f"{name}.elided_peak"] = eli["peak"]
        yield name, g, rows, mat, eli, metrics


def scaling_metrics():
    """Gated peaks of the `scheduler_scaling` bench's layered models
    (mirrors `rust/benches/scheduler_scaling.rs`): default and optimal
    peaks at 100/300/1000 ops, plus the planned peak at 100 ops under
    SCALING_OPTS. The 300/1000-op planned peaks are deliberately not
    mirrored — the naive full-DP scoring here is too slow at those sizes,
    which is exactly the gap the Rust incremental planner closes."""
    metrics = {}
    for n in (100, 300, 1000):
        g = layered(Rng(n), n)
        name = f"layered{n}"
        metrics[f"{name}.default_peak"] = simulate(g, g.default_order())[1]
        metrics[f"{name}.reorder_peak"] = optimal(g)[1]
        if n == 100:
            metrics[f"{name}.planned_peak"] = optimize(g, SCALING_OPTS)["peak"]
    return metrics


SERVING_SEED = 19_100_511
SERVING_CACHE_CAP = 24
SERVING_ZIPF_DRAWS = 400
SERVING_MODELS = 8  # the 7-model zoo + the uploaded cnn_int8.tflite fixture
SERVING_BOARDS = 4
SERVING_SHED = 4  # phase C: 12 submits into queue_cap 8 shed exactly 4


class LruSim:
    """Tick-counter LRU, bit-exact to `rust/src/coordinator/cache.rs`:
    `get` increments the tick and promotes on hit; `insert` increments
    the tick, refreshes in place if present, else evicts the minimum-tick
    entry when full. Ticks never repeat, so eviction order — and with it
    every hit/miss/eviction counter — is fully deterministic."""

    def __init__(self, cap):
        self.entries = {}  # key -> last-touched tick
        self.tick = 0
        self.cap = max(cap, 1)
        self.hits = self.misses = self.evictions = 0

    def get(self, key):
        self.tick += 1
        if key in self.entries:
            self.entries[key] = self.tick
            self.hits += 1
            return True
        self.misses += 1
        return False

    def insert(self, key):
        self.tick += 1
        if key in self.entries:
            self.entries[key] = self.tick
            return
        if len(self.entries) >= self.cap:
            victim = min(self.entries, key=self.entries.get)
            del self.entries[victim]
            self.evictions += 1
        self.entries[key] = self.tick


def zipf_rank(rng, weights):
    total = sum(weights)
    draw = rng.below(total)
    for r, w in enumerate(weights):
        if draw < w:
            return r
        draw -= w
    return len(weights) - 1


def serving_metrics():
    """Gated `_floor` counters of the `plan_serving` bench (mirrors
    `rust/benches/plan_serving.rs` phases A and C): a coverage sweep over
    all (model, board) ranks, then SERVING_ZIPF_DRAWS zipf(1)-distributed
    requests — integer weights 1e6/(r+1), ranks drawn from the mirrored
    xoshiro256** stream — against the tick-LRU plan cache. Each request
    is one `get`; each miss computes and `insert`s, exactly like
    `PlanService::plan` on a single worker."""
    n_ranks = SERVING_MODELS * SERVING_BOARDS
    cache = LruSim(SERVING_CACHE_CAP)
    for rank in range(n_ranks):  # coverage sweep: every key is distinct
        cache.get(rank)
        cache.insert(rank)
    assert cache.misses == n_ranks and cache.evictions == n_ranks - SERVING_CACHE_CAP
    hits_before = cache.hits
    weights = [1_000_000 // (r + 1) for r in range(n_ranks)]
    rng = Rng(SERVING_SEED)
    for _ in range(SERVING_ZIPF_DRAWS):
        rank = zipf_rank(rng, weights)
        if not cache.get(rank):
            cache.insert(rank)
    zipf_hits = cache.hits - hits_before
    return {
        "fleet.coverage_boards_floor": SERVING_BOARDS,
        "fleet.coverage_models_floor": SERVING_MODELS,
        "fleet.plans_served_floor": n_ranks + SERVING_ZIPF_DRAWS,
        "fleet.shed_floor": SERVING_SHED,
        "fleet.zipf_hits_floor": zipf_hits,
    }


def codegen_zoo():
    """`(label, graph, mirror_arena)` rows matching `rust/benches/codegen.rs`:
    every zoo model in each dtype the audit pipeline prepares it for
    (figure1 is u8-only; the CNNs come in f32 and i8), plus the imported
    int8 TFLite fixture. `mirror_arena` is False for `tflitecnn_i8`: the
    importer assigns tensor ids in flatbuffer order, this mirror in
    builder order, and best-fit placement is id-tie-broken — the names
    agree but the arena layout legitimately differs."""
    rows = [("figure1_u8", figure1(), True)]
    for name, make in (
        ("mobilenet", mobilenet),
        ("swiftnet", swiftnet),
        ("resnet", resnet),
        ("audionet", audionet),
        ("streamnet", streamnet),
        ("tiny", tiny),
    ):
        rows.append((f"{name}_f32", make(dsize=4), True))
        rows.append((f"{name}_i8", make(dsize=1), True))
    rows.append(("tflitecnn_i8", tflitecnn(), False))
    return rows


def codegen_metrics():
    """Gated `_bytes` sizes of the `codegen` bench: the static arena each
    reorder-only artifact declares (DP-optimal order + best-fit
    placement) and the rodata of its baked-in weight tables (the sum of
    weight-tensor bytes; biases are 4-byte f32/i32 in every dtype)."""
    metrics = {}
    for label, g, mirror_arena in codegen_zoo():
        if mirror_arena:
            order, _ = optimal(g)
            metrics[f"{label}.arena_bytes"] = best_fit(g, order)
        metrics[f"{label}.rodata_bytes"] = sum(
            t.bytes() for t in g.tensors if t.is_weight
        )
    return metrics


def live_csv(g, order):
    """Per-op live-set CSV keyed by tensor names.

    Byte-identical to `rust/src/trace/mod.rs::live_csv`: header
    `step,op,bytes,resident`, one row per scheduled op, resident tensor
    names sorted lexicographically and space-joined. Names — not ids —
    are the portable identity (the Rust TFLite importer and this mirror
    assign different tensor ids to tflitecnn but agree on names), which
    is what lets CI `diff` this output against
    `mcu-reorder trace --model M --format csv`.
    """
    steps, _, _ = simulate(g, order)
    out = ["step,op,bytes,resident"]
    for i, (opid, live, nbytes) in enumerate(steps):
        names = sorted(g.tensors[t].name for t in live)
        out.append(f"{i},{g.ops[opid].name},{nbytes},{' '.join(names)}")
    return "\n".join(out) + "\n"


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", action="store_true",
                    help="print BENCH_baseline/partial_exec.json gated metrics")
    ap.add_argument("--scaling-baseline", action="store_true",
                    help="print BENCH_baseline/scheduler_scaling.json gated "
                         "metrics (layered synthetic models)")
    ap.add_argument("--serving-baseline", action="store_true",
                    help="print BENCH_baseline/serving.json gated _floor "
                         "counters (simulated plan-serving fleet)")
    ap.add_argument("--codegen-baseline", action="store_true",
                    help="print BENCH_baseline/codegen.json gated _bytes "
                         "sizes (AOT artifact arena + rodata)")
    ap.add_argument("--report", action="store_true",
                    help="print the full per-model plan report")
    ap.add_argument("--check", metavar="BENCH_JSON",
                    help="recompute every *_peak / *_floor metric and fail "
                         "on any mismatch with the given BENCH_*.json (the "
                         "Rust-vs-mirror drift gate; dispatches on the "
                         "report's \"bench\" field)")
    ap.add_argument("--trace", metavar="MODEL",
                    help="print the per-op live-set CSV for MODEL, "
                         "byte-identical to `mcu-reorder trace --model "
                         "MODEL --format csv` (the Rust-vs-mirror "
                         "timeline gate)")
    ap.add_argument("--order", choices=["default", "optimal"],
                    default="default",
                    help="schedule used by --trace (default: default)")
    args = ap.parse_args(argv)
    if args.trace:
        for name, g in zoo():
            if name == args.trace:
                order = g.default_order() if args.order == "default" else optimal(g)[0]
                sys.stdout.write(live_csv(g, order))
                return 0
        print(f"unknown model {args.trace!r} (want one of "
              f"{', '.join(n for n, _ in zoo())})", file=sys.stderr)
        return 1
    check_doc = None
    check_bench = None
    if args.check:
        with open(args.check, "r", encoding="utf-8") as f:
            check_doc = json.load(f)
        check_bench = check_doc.get("bench", "partial_exec")
    need_zoo = (args.report or args.baseline
                or (args.check
                    and check_bench not in ("scheduler_scaling", "serving",
                                            "codegen")))
    metrics = {}
    if need_zoo:
        for name, g, rows, mat, eli, metrics in bench_metrics():
            if args.report:
                print(f"== {name}")
                print(f"   default {simulate(g, g.default_order())[1]}  "
                      f"reorder {optimal(g)[1]}  rows {rows['peak']}  "
                      f"mat {mat['peak']}  elided {eli['peak']}")
                for seg, factor, axis, elide, before, after in eli["steps"]:
                    tag = ", join elided" if elide else ""
                    print(f"   split {seg} x{factor} along {axis}{tag}: {before} -> {after}")
    if args.baseline:
        doc = {"bench": "partial_exec",
               "metrics": {k: v for k, v in sorted(metrics.items())},
               "timings": []}
        print(json.dumps(doc, indent=2))
    if args.scaling_baseline:
        doc = {"bench": "scheduler_scaling",
               "metrics": {k: v for k, v in sorted(scaling_metrics().items())},
               "timings": []}
        print(json.dumps(doc, indent=2))
    if args.serving_baseline:
        doc = {"bench": "serving",
               "metrics": {k: v for k, v in sorted(serving_metrics().items())},
               "timings": []}
        print(json.dumps(doc, indent=2))
    if args.codegen_baseline:
        doc = {"bench": "codegen",
               "metrics": {k: v for k, v in sorted(codegen_metrics().items())},
               "timings": []}
        print(json.dumps(doc, indent=2))
    if args.check:
        if check_bench == "scheduler_scaling":
            mirror_metrics = scaling_metrics()
        elif check_bench == "serving":
            mirror_metrics = serving_metrics()
        elif check_bench == "codegen":
            mirror_metrics = codegen_metrics()
        else:
            mirror_metrics = metrics
        reported = check_doc.get("metrics", {})
        bad = 0
        for key, val in sorted(mirror_metrics.items()):
            if not (key.endswith("_peak") or key.endswith("_floor")
                    or key.endswith("_bytes")):
                continue
            if key not in reported:
                print(f"MISSING {key}: mirror {val}, absent from {args.check}")
                bad += 1
            elif int(reported[key]) != val:
                print(f"DRIFT {key}: mirror {val} vs rust {reported[key]:.0f}")
                bad += 1
            else:
                print(f"ok  {key}: {val}")
        if bad:
            print(f"\n{bad} metric(s) drifted between the Rust side and "
                  "the mirror", file=sys.stderr)
            return 1
        print("\nmirror: all gated metrics agree")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

#!/usr/bin/env python3
"""Bench-regression gate.

Compares the freshly-written ``BENCH_<name>.json`` reports (produced by
``cargo bench``) against the committed baselines in ``BENCH_baseline/``
and fails (exit 1) if any gated metric regressed.

Gated metrics come in two polarities, both fully deterministic (they
come from the analytic scheduler and the deterministic serving
simulation, not from timing). Timing rows are reported but never gated.

  - ``*_peak``  keys: peak SRAM in bytes, LOWER is better
  - ``*_bytes`` keys: deployable artifact sizes (codegen arena/rodata)
    in bytes, LOWER is better
  - ``*_floor`` keys: counters that must not drop (plans served, cache
    hits, coverage, shed decisions), HIGHER is better

Usage:
    python3 tools/bench_compare/compare.py <baseline_dir> <current_dir>

Baseline files are named ``<bench>.json`` (e.g. ``partial_exec.json``)
and share the report schema: ``{"bench": ..., "metrics": {...}}``.
Current files are the ``BENCH_<bench>.json`` the bench binaries write.

Rules (inverted for ``_floor`` keys):
  - current value worse than baseline  -> REGRESSION (fail)
    (``_peak``: current > baseline; ``_floor``: current < baseline)
  - current value no worse             -> ok (improvement is reported)
  - baseline key missing from current  -> MISSING (fail: coverage loss)
  - current key missing from baseline  -> new (reported, not gated)

To refresh a baseline after an intentional change:
    cargo bench --bench partial_exec
    python3 tools/bench_compare/compare.py --refresh BENCH_baseline .
which copies the gated metrics of the current reports over the baseline
files (review the diff before committing).
"""

import json
import pathlib
import sys

GATED_SUFFIX = "_peak"  # lower is better
BYTES_SUFFIX = "_bytes"  # lower is better (codegen artifact sizes)
FLOOR_SUFFIX = "_floor"  # higher is better


def load_metrics(path):
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    return {k: v for k, v in doc.get("metrics", {}).items()}


def gated(metrics):
    return {
        k: v
        for k, v in metrics.items()
        if k.endswith(GATED_SUFFIX) or k.endswith(BYTES_SUFFIX) or k.endswith(FLOOR_SUFFIX)
    }


def regressed(key, cur_val, base_val):
    if key.endswith(FLOOR_SUFFIX):
        return cur_val < base_val
    return cur_val > base_val


def refresh(baseline_dir, current_dir):
    for base_path in sorted(baseline_dir.glob("*.json")):
        cur_path = current_dir / f"BENCH_{base_path.stem}.json"
        if not cur_path.exists():
            print(f"refresh: {cur_path} not found; run the bench first", file=sys.stderr)
            return 1
        cur = gated(load_metrics(cur_path))
        doc = {"bench": base_path.stem, "metrics": dict(sorted(cur.items())), "timings": []}
        base_path.write_text(json.dumps(doc, indent=2) + "\n", encoding="utf-8")
        print(f"refreshed {base_path} ({len(cur)} gated metrics)")
    return 0


def compare(baseline_dir, current_dir):
    failures = []
    checked = 0
    for base_path in sorted(baseline_dir.glob("*.json")):
        bench = base_path.stem
        cur_path = current_dir / f"BENCH_{bench}.json"
        if not cur_path.exists():
            failures.append(f"{bench}: current report {cur_path} not found (bench not run?)")
            continue
        base = gated(load_metrics(base_path))
        cur = load_metrics(cur_path)
        for key, base_val in sorted(base.items()):
            if key not in cur:
                failures.append(f"{bench}: metric {key} missing from current report")
                continue
            checked += 1
            cur_val = cur[key]
            if regressed(key, cur_val, base_val):
                rel = "<" if key.endswith(FLOOR_SUFFIX) else ">"
                failures.append(
                    f"{bench}: {key} regressed: {cur_val:.0f} {rel} baseline {base_val:.0f}"
                )
            elif cur_val != base_val:
                print(f"ok  {bench}.{key}: improved {base_val:.0f} -> {cur_val:.0f}")
            else:
                print(f"ok  {bench}.{key}: {cur_val:.0f}")
        for key in sorted(gated(cur)):
            if key not in base:
                print(f"new {bench}.{key}: {cur[key]:.0f} (not in baseline; not gated)")
        # Cross-metric invariant: the elided planner scores a superset of
        # the materialized planner's moves, so its plan should not lose.
        # Beam pruning makes this a strong expectation rather than a
        # theorem — surface violations loudly, but do not gate on them.
        for key, val in sorted(cur.items()):
            if not key.endswith(".elided_peak"):
                continue
            mat_key = key.replace(".elided_peak", ".split_reorder_peak")
            if mat_key in cur and val > cur[mat_key]:
                print(
                    f"WARNING {bench}.{key}: elided plan {val:.0f} above "
                    f"materialized plan {cur[mat_key]:.0f} (beam pruning artifact?)"
                )
    print(f"\nchecked {checked} gated metric(s)")
    if failures:
        print("\nBENCH REGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("bench-regression gate: green")
    return 0


def main(argv):
    args = [a for a in argv[1:] if a != "--refresh"]
    do_refresh = "--refresh" in argv[1:]
    if len(args) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline_dir = pathlib.Path(args[0])
    current_dir = pathlib.Path(args[1])
    if not baseline_dir.is_dir():
        print(f"baseline dir {baseline_dir} not found", file=sys.stderr)
        return 2
    if do_refresh:
        return refresh(baseline_dir, current_dir)
    return compare(baseline_dir, current_dir)


if __name__ == "__main__":
    sys.exit(main(sys.argv))

#!/usr/bin/env python3
"""Hermetic .tflite fixture generator (no TensorFlow dependency).

Writes two tiny CNN models with fully deterministic, formula-defined
weights through a hand-rolled flatbuffer builder:

  cnn_f32.tflite   float32 weights/activations
  cnn_int8.tflite  int8 weights + per-tensor affine quantization

The builder here is an implementation of the flatbuffers wire format
*independent* from the Rust reader/writer in ``rust/src/tflite/flatbuf.rs``
— that independence is what makes the golden import tests meaningful
(two implementations agreeing on the bytes, not one talking to itself).

The model ("tflitecnn") covers the full supported operator subset:
CONV_2D (+fused RELU6), DEPTHWISE_CONV_2D (+fused RELU6), CONV_2D 1x1
(+fused RELU), ADD, CONCATENATION, MAX_POOL_2D, MEAN (global spatial),
RESHAPE, FULLY_CONNECTED, SOFTMAX.

Weight values use only dyadic rationals (k / 2^n), which are exactly
representable in f32, so the Rust test suite re-derives bit-identical
expectations from the same integer formulas (see WEIGHT_FORMULAS below
and rust/tests/integration_tflite.rs).

Usage:
    python3 tools/tflite_fixtures/gen.py --out-dir target/tflite_fixtures
"""

import argparse
import os
import struct
import sys

# ---------------------------------------------------------------------------
# flatbuffer builder (back-to-front, mirrors the canonical algorithm)
# ---------------------------------------------------------------------------


class Builder:
    """Byte stack in reverse order: rev[0] is the final file's last byte."""

    def __init__(self):
        self.rev = bytearray()
        self.max_align = 1

    def prep(self, align, extra):
        self.max_align = max(self.max_align, align)
        while (len(self.rev) + extra) % align != 0:
            self.rev.append(0)

    def push(self, data):
        """Push bytes that must appear in `data` order in the file."""
        self.rev.extend(reversed(data))

    def push_u16(self, v):
        self.push(struct.pack("<H", v))

    def push_u32(self, v):
        self.push(struct.pack("<I", v))

    def push_uoffset(self, target):
        assert target <= len(self.rev), "forward reference to unwritten object"
        self.push_u32(len(self.rev) + 4 - target)

    def byte_vector(self, data):
        self.prep(4, len(data) + 4)
        self.push(bytes(data))
        self.push_u32(len(data))
        return len(self.rev)

    def string(self, s):
        raw = s.encode("utf-8")
        self.prep(4, len(raw) + 1 + 4)
        self.rev.append(0)  # NUL terminator
        self.push(raw)
        self.push_u32(len(raw))
        return len(self.rev)

    def _scalar_vector(self, fmt, size, vals):
        # Canonical two-step vector prep: elements `size`-aligned, which
        # leaves the u32 length word at 4 mod max(size, 4).
        self.prep(4, len(vals) * size)
        self.prep(size, len(vals) * size)
        for v in reversed(vals):
            self.push(struct.pack(fmt, v))
        self.push_u32(len(vals))
        return len(self.rev)

    def i32_vector(self, vals):
        return self._scalar_vector("<i", 4, vals)

    def f32_vector(self, vals):
        return self._scalar_vector("<f", 4, vals)

    def i64_vector(self, vals):
        return self._scalar_vector("<q", 8, vals)

    def offset_vector(self, targets):
        self.prep(4, len(targets) * 4 + 4)
        for t in reversed(targets):
            self.push_uoffset(t)
        self.push_u32(len(targets))
        return len(self.rev)

    def table(self, fields):
        """fields: list of (field_id, kind, value); kind in
        {u8,i8,bool,i32,u32,f32,off}. Absent fields are simply omitted."""
        start = len(self.rev)
        slots = []
        for fid, kind, val in sorted(fields, key=lambda f: -f[0]):
            if kind in ("u8", "i8", "bool"):
                self.prep(1, 0)
                self.rev.append(val & 0xFF)  # two's complement for i8
            elif kind == "i32":
                self.prep(4, 0)
                self.push(struct.pack("<i", val))
            elif kind == "u32":
                self.prep(4, 0)
                self.push(struct.pack("<I", val))
            elif kind == "f32":
                self.prep(4, 0)
                self.push(struct.pack("<f", val))
            elif kind == "off":
                self.prep(4, 0)
                self.push_uoffset(val)
            else:
                raise ValueError(kind)
            slots.append((fid, len(self.rev)))
        n_slots = max((fid + 1 for fid, _, _ in fields), default=0)
        vtable_len = 4 + 2 * n_slots
        self.prep(4, 0)
        self.push(struct.pack("<i", vtable_len))  # soffset: vtable sits just before
        table_pos = len(self.rev)
        table_len = table_pos - start
        by_id = dict(slots)
        for fid in reversed(range(n_slots)):
            self.push_u16(table_pos - by_id[fid] if fid in by_id else 0)
        self.push_u16(table_len)
        self.push_u16(vtable_len)
        return table_pos

    def finish(self, root, ident=b"TFL3"):
        self.prep(max(self.max_align, 4), 8)
        self.push(ident)
        self.push_uoffset(root)
        return bytes(reversed(self.rev))


# ---------------------------------------------------------------------------
# TFLite schema constants (subset)
# ---------------------------------------------------------------------------

FLOAT32, INT32, INT8 = 0, 2, 9

ADD, AVERAGE_POOL_2D, CONCATENATION, CONV_2D, DEPTHWISE_CONV_2D = 0, 1, 2, 3, 4
FULLY_CONNECTED, MAX_POOL_2D, RELU, RELU6, RESHAPE, SOFTMAX, MEAN = 9, 17, 19, 21, 22, 25, 40

OPT_NONE, OPT_CONV2D, OPT_DWCONV2D, OPT_POOL2D = 0, 1, 2, 5
OPT_FULLY_CONNECTED, OPT_SOFTMAX, OPT_CONCATENATION, OPT_ADD = 8, 9, 10, 11
OPT_RESHAPE, OPT_REDUCER = 17, 27

ACT_NONE, ACT_RELU, ACT_RELU6 = 0, 1, 3
PAD_SAME, PAD_VALID = 0, 1

# ---------------------------------------------------------------------------
# deterministic weights (WEIGHT_FORMULAS — mirrored by the Rust tests)
# ---------------------------------------------------------------------------


def wq(i, mul, add):
    """Deterministic int8 weight stream: ((i*mul + add) % 253) - 126."""
    return ((i * mul + add) % 253) - 126


def bq(i, mul):
    """Deterministic small bias stream: ((i*mul) % 21) - 10."""
    return ((i * mul) % 21) - 10


def weights_i8(n, mul, add):
    return [wq(i, mul, add) for i in range(n)]


def weights_f32(n, mul, add):
    return [wq(i, mul, add) / 128.0 for i in range(n)]


def bias_i32(n, mul):
    return [bq(i, mul) for i in range(n)]


def bias_f32(n, mul):
    return [bq(i, mul) / 16.0 for i in range(n)]


def pack_i8(vals):
    return struct.pack(f"{len(vals)}b", *vals)


def pack_i32(vals):
    return struct.pack(f"<{len(vals)}i", *vals)


def pack_f32(vals):
    return struct.pack(f"<{len(vals)}f", *vals)


# (mul, add) per weight tensor — the single source of truth.
FORMULAS = {
    "conv1.w": (37, 11),
    "conv1.b": (19, 0),
    "dw1.w": (53, 7),
    "dw1.b": (5, 0),
    "pwa.w": (71, 3),
    "pwa.b": (13, 0),
    "fc.w": (89, 5),
    "fc.b": (7, 0),
}

# Per-tensor quantization of the int8 fixture: (scale, zero_point).
# Scales are dyadic (exact in f32). MaxPool/Mean/Reshape outputs share
# their input's parameters (domain-preserving kernels); the softmax
# output uses the TFLite convention 1/256, zp -128.
QPARAMS = {
    "input": (0.0625, 1),
    "conv1": (0.046875, -10),
    "dw1": (0.03125, 4),
    # Concatenation inputs must share the output's quantization (a real
    # TFLite kernel constraint — concatenation.cc refuses to prepare
    # otherwise), so pwa lives in the cat/add1 domain.
    "pwa": (0.0625, 0),
    "add1": (0.0625, 0),
    "cat": (0.0625, 0),
    "pool": (0.0625, 0),
    "mean": (0.0625, 0),
    "reshape": (0.0625, 0),
    "fc": (0.125, 3),
    "softmax": (0.00390625, -128),
    # weight scales (zero point 0, symmetric)
    "conv1.w": (0.015625, 0),
    "dw1.w": (0.015625, 0),
    "pwa.w": (0.015625, 0),
    "fc.w": (0.015625, 0),
}


def model_bytes(dtype):
    """Build the tflitecnn fixture; dtype is 'f32' or 'int8'."""
    int8 = dtype == "int8"
    b = Builder()

    # --- buffers (index 0 is the canonical empty sentinel) -----------------
    buffers = [b""]

    def buf(data):
        buffers.append(data)
        return len(buffers) - 1

    def wbuf(name, n):
        mul, add = FORMULAS[name]
        if name.endswith(".b"):
            return buf(pack_i32(bias_i32(n, mul)) if int8 else pack_f32(bias_f32(n, mul)))
        return buf(pack_i8(weights_i8(n, mul, add)) if int8 else pack_f32(weights_f32(n, mul, add)))

    # --- tensors -----------------------------------------------------------
    ttype = INT8 if int8 else FLOAT32
    tensors = []  # (shape, type, buffer, name, qname)

    def tensor(name, shape, ty=None, buffer=0, qname=None):
        tensors.append((shape, ttype if ty is None else ty, buffer, name, qname))
        return len(tensors) - 1

    t_in = tensor("input", [1, 16, 16, 2], qname="input")
    t_conv1_w = tensor("conv1.w", [8, 3, 3, 2], buffer=wbuf("conv1.w", 8 * 3 * 3 * 2),
                       qname="conv1.w")
    t_conv1_b = tensor("conv1.b", [8], ty=INT32 if int8 else FLOAT32,
                       buffer=wbuf("conv1.b", 8))
    t_conv1 = tensor("conv1", [1, 16, 16, 8], qname="conv1")
    t_dw1_w = tensor("dw1.w", [1, 3, 3, 8], buffer=wbuf("dw1.w", 3 * 3 * 8), qname="dw1.w")
    t_dw1_b = tensor("dw1.b", [8], ty=INT32 if int8 else FLOAT32, buffer=wbuf("dw1.b", 8))
    t_dw1 = tensor("dw1", [1, 8, 8, 8], qname="dw1")
    t_pwa_w = tensor("pwa.w", [8, 1, 1, 8], buffer=wbuf("pwa.w", 8 * 8), qname="pwa.w")
    t_pwa_b = tensor("pwa.b", [8], ty=INT32 if int8 else FLOAT32, buffer=wbuf("pwa.b", 8))
    t_pwa = tensor("pwa", [1, 8, 8, 8], qname="pwa")
    t_add1 = tensor("add1", [1, 8, 8, 8], qname="add1")
    t_cat = tensor("cat", [1, 8, 8, 16], qname="cat")
    t_pool = tensor("pool", [1, 4, 4, 16], qname="pool")
    t_mean_axes = tensor("mean.axes", [2], ty=INT32, buffer=buf(pack_i32([1, 2])))
    t_mean = tensor("mean", [1, 1, 1, 16], qname="mean")
    t_shape = tensor("reshape.shape", [2], ty=INT32, buffer=buf(pack_i32([1, 16])))
    t_reshape = tensor("reshape", [1, 16], qname="reshape")
    t_fc_w = tensor("fc.w", [4, 16], buffer=wbuf("fc.w", 4 * 16), qname="fc.w")
    t_fc_b = tensor("fc.b", [4], ty=INT32 if int8 else FLOAT32, buffer=wbuf("fc.b", 4))
    t_fc = tensor("fc", [1, 4], qname="fc")
    t_sm = tensor("softmax", [1, 4], qname="softmax")

    # Converter-style metadata stamp (16-byte buffer, like TF's
    # min_runtime_version) — exercises the exporter's metadata
    # preservation end to end.
    meta_buf = buf(b"1.5.0" + b"\x00" * 11)

    # --- operators (vector order == execution order) -----------------------
    opcodes = [CONV_2D, DEPTHWISE_CONV_2D, ADD, CONCATENATION, MAX_POOL_2D,
               MEAN, RESHAPE, FULLY_CONNECTED, SOFTMAX]
    oc_index = {c: i for i, c in enumerate(opcodes)}

    def conv_opts(bld, act, stride):
        return OPT_CONV2D, bld.table([
            (0, "i8", PAD_SAME), (1, "i32", stride), (2, "i32", stride), (3, "i8", act),
        ])

    operators = [
        # (opcode, inputs, outputs, options_builder)
        (CONV_2D, [t_in, t_conv1_w, t_conv1_b], [t_conv1],
         lambda bld: conv_opts(bld, ACT_RELU6, 1)),
        (DEPTHWISE_CONV_2D, [t_conv1, t_dw1_w, t_dw1_b], [t_dw1],
         lambda bld: (OPT_DWCONV2D, bld.table([
             (0, "i8", PAD_SAME), (1, "i32", 2), (2, "i32", 2),
             (3, "i32", 1), (4, "i8", ACT_RELU6)]))),
        (CONV_2D, [t_dw1, t_pwa_w, t_pwa_b], [t_pwa],
         lambda bld: conv_opts(bld, ACT_RELU, 1)),
        (ADD, [t_dw1, t_pwa], [t_add1],
         lambda bld: (OPT_ADD, bld.table([(0, "i8", ACT_NONE)]))),
        (CONCATENATION, [t_add1, t_pwa], [t_cat],
         lambda bld: (OPT_CONCATENATION, bld.table([(0, "i32", 3), (1, "i8", ACT_NONE)]))),
        (MAX_POOL_2D, [t_cat], [t_pool],
         lambda bld: (OPT_POOL2D, bld.table([
             (0, "i8", PAD_VALID), (1, "i32", 2), (2, "i32", 2),
             (3, "i32", 2), (4, "i32", 2), (5, "i8", ACT_NONE)]))),
        (MEAN, [t_pool, t_mean_axes], [t_mean],
         lambda bld: (OPT_REDUCER, bld.table([(0, "bool", 1)]))),
        (RESHAPE, [t_mean, t_shape], [t_reshape],
         lambda bld: (OPT_RESHAPE, bld.table([(0, "off", bld.i32_vector([1, 16]))]))),
        (FULLY_CONNECTED, [t_reshape, t_fc_w, t_fc_b], [t_fc],
         lambda bld: (OPT_FULLY_CONNECTED, bld.table([(0, "i8", ACT_NONE)]))),
        (SOFTMAX, [t_fc], [t_sm],
         lambda bld: (OPT_SOFTMAX, bld.table([(0, "f32", 1.0)]))),
    ]

    # --- serialize ---------------------------------------------------------
    buffer_offs = []
    for data in buffers:
        if data:
            v = b.byte_vector(data)
            buffer_offs.append(b.table([(0, "off", v)]))
        else:
            buffer_offs.append(b.table([]))
    buffers_vec = b.offset_vector(buffer_offs)

    code_offs = [
        b.table([(0, "i8", c), (2, "i32", 1), (3, "i32", c)]) for c in opcodes
    ]
    codes_vec = b.offset_vector(code_offs)

    tensor_offs = []
    for shape, ty, buffer, name, qname in tensors:
        fields = [(0, "off", b.i32_vector(shape)), (3, "off", b.string(name))]
        if ty != 0:
            fields.append((1, "i8", ty))
        if buffer != 0:
            fields.append((2, "u32", buffer))
        if int8 and qname is not None:
            scale, zp = QPARAMS[qname]
            q = b.table([
                (2, "off", b.f32_vector([scale])),
                (3, "off", b.i64_vector([zp])),
            ])
            fields.append((4, "off", q))
        tensor_offs.append(b.table(fields))
    tensors_vec = b.offset_vector(tensor_offs)

    op_offs = []
    for code, ins, outs, mkopts in operators:
        ty, opts = mkopts(b)
        fields = [
            (0, "u32", oc_index[code]),
            (1, "off", b.i32_vector(ins)),
            (2, "off", b.i32_vector(outs)),
            (3, "u8", ty),
            (4, "off", opts),
        ]
        op_offs.append(b.table(fields))
    ops_vec = b.offset_vector(op_offs)

    sg = b.table([
        (0, "off", tensors_vec),
        (1, "off", b.i32_vector([t_in])),
        (2, "off", b.i32_vector([t_sm])),
        (3, "off", ops_vec),
        (4, "off", b.string("tflitecnn")),
    ])
    subgraphs_vec = b.offset_vector([sg])

    meta_name = b.string("min_runtime_version")
    meta = b.table([(0, "off", meta_name), (1, "u32", meta_buf)])
    metadata_vec = b.offset_vector([meta])

    root = b.table([
        (0, "u32", 3),
        (1, "off", codes_vec),
        (2, "off", subgraphs_vec),
        (3, "off", b.string(f"tflitecnn {dtype} fixture (mcu-reorder)")),
        (4, "off", buffers_vec),
        (6, "off", metadata_vec),
    ])
    return b.finish(root)


def write_atomic(path, data):
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)


def fingerprint(data):
    """FNV-1a 64 — must match fixtures::fingerprint in rust/src/tflite/mod.rs."""
    h = 0xCBF29CE484222325
    for byte in data:
        h = ((h ^ byte) * 0x100000001B3) & ((1 << 64) - 1)
    return h


def main(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="target/tflite_fixtures")
    args = ap.parse_args(argv)
    os.makedirs(args.out_dir, exist_ok=True)
    for dtype, name in [("f32", "cnn_f32.tflite"), ("int8", "cnn_int8.tflite")]:
        data = model_bytes(dtype)
        path = os.path.join(args.out_dir, name)
        write_atomic(path, data)
        print(f"wrote {path} ({len(data)} bytes)")
    # Freshness stamp: the Rust fixtures::ensure() helper regenerates
    # whenever this does not match the generator source's fingerprint.
    with open(__file__, "rb") as f:
        stamp = f"{fingerprint(f.read()):016x}"
    write_atomic(os.path.join(args.out_dir, "gen.py.stamp"), stamp.encode())
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
